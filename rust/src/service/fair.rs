//! `FairQueue`: deficit-weighted round-robin across per-client queues.
//!
//! FIFO admission lets one greedy client fill the queue and starve
//! everyone behind it. This layer replaces FIFO ordering in front of
//! the coordinator: each client gets its own bounded queue, and a
//! fixed number of dispatch slots into the inner service are handed
//! out by deficit round-robin (DRR) — every scheduling round gives
//! each backlogged client `weight` credits, and dispatches cost one
//! credit — so a client that floods only ever lengthens *its own*
//! queue while light clients keep flowing at their fair share.
//!
//! Overflowing a per-client queue is a rejection (`Err(Overloaded)`,
//! counted in `Metrics::fair_shed` and attributed to the client), not
//! a longer wait: the greedy client absorbs the sheds, which is the
//! isolation property `benches/bench_service.rs` measures.
//!
//! Like [`super::limit::ConcurrencyLimit`] this layer *queues* (the
//! calling thread blocks until scheduled); unlike it, the unblock
//! order is fair rather than condvar-arbitrary, and the queue bound is
//! per client rather than global.
//!
//! **Sessions are charged per turn.** A multi-turn session request is
//! scheduled like any other call from its client: each turn costs one
//! DRR credit when it dispatches, so a client running a long session
//! pays for it turn by turn at its fair share — holding a pinned
//! session confers no scheduling priority, and a session client that
//! floods turns backlogs only its own queue like any other flood.

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::metrics::{ClientStats, Metrics};

use super::{Keyed, Layer, Readiness, Service, ServiceError};

/// One client's scheduling state: its FIFO of waiting tickets plus the
/// DRR credit balance.
struct ClientQueue {
    id: String,
    weight: u32,
    deficit: f64,
    waiting: VecDeque<u64>,
    stats: Arc<ClientStats>,
}

struct FqState {
    /// Backlogged clients in rotation order. A client leaves the
    /// rotation when its queue empties and re-enters on next arrival.
    clients: Vec<ClientQueue>,
    /// Rotation position for the DRR scan.
    cursor: usize,
    /// Dispatch slots currently held by in-flight calls.
    active: usize,
    next_ticket: u64,
    /// Tickets selected for dispatch whose owner threads have not yet
    /// picked them up.
    granted: HashSet<u64>,
}

/// Pick the next ticket under deficit-weighted round-robin, or `None`
/// if every queue is empty. Scans from the cursor for a backlogged
/// client holding credit; when no one holds credit, tops every
/// backlogged client up by its weight (one scheduling "round").
fn drr_pick(st: &mut FqState) -> Option<u64> {
    if st.clients.iter().all(|c| c.waiting.is_empty()) {
        return None;
    }
    loop {
        let n = st.clients.len();
        for k in 0..n {
            let i = (st.cursor + k) % n;
            let c = &mut st.clients[i];
            if c.waiting.is_empty() || c.deficit < 1.0 {
                continue;
            }
            c.deficit -= 1.0;
            let ticket = c.waiting.pop_front().expect("queue checked non-empty");
            c.stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
            let emptied = c.waiting.is_empty();
            let exhausted = c.deficit < 1.0;
            if emptied {
                // Classic DRR: an emptied queue forfeits leftover credit
                // (idle clients must not hoard priority) and leaves the
                // rotation until it has traffic again.
                st.clients.remove(i);
                if st.clients.is_empty() {
                    st.cursor = 0;
                } else {
                    if i < st.cursor {
                        st.cursor -= 1;
                    }
                    if st.cursor >= st.clients.len() {
                        st.cursor = 0;
                    }
                }
            } else if exhausted {
                st.cursor = (i + 1) % n;
            } else {
                st.cursor = i;
            }
            return Some(ticket);
        }
        // No backlogged client holds credit: start a new round. Weights
        // are >= 1, so the next scan is guaranteed to dispatch.
        for c in st.clients.iter_mut() {
            if !c.waiting.is_empty() {
                c.deficit += c.weight.max(1) as f64;
            }
        }
    }
}

/// Weighted-fair queueing in front of a service; see the
/// [module docs](self).
///
/// ```
/// use std::sync::Arc;
/// use normq::coordinator::metrics::Metrics;
/// use normq::coordinator::ServeRequest;
/// use normq::service::{Echo, Service, Stack};
///
/// let metrics = Arc::new(Metrics::new());
/// // 2 dispatch slots, per-client queues bounded at 64.
/// let svc = Stack::new()
///     .fair_queue(2, 64, Arc::clone(&metrics))
///     .service(Echo::instant());
/// let resp = svc
///     .call(ServeRequest::from_client(vec!["tree".into()], "alice"))
///     .unwrap();
/// assert_eq!(resp.client_id, "alice");
/// assert_eq!(metrics.client("alice").queue_depth.load(std::sync::atomic::Ordering::Relaxed), 0);
/// ```
pub struct FairQueue<S> {
    inner: S,
    /// Concurrent dispatches permitted into the inner service.
    concurrency: usize,
    /// Waiting-ticket bound per client; overflow is shed.
    queue_cap: usize,
    state: Mutex<FqState>,
    wakeup: Condvar,
    metrics: Arc<Metrics>,
}

impl<S> FairQueue<S> {
    /// Wrap `inner`, dispatching at most `concurrency` calls into it at
    /// once and holding at most `queue_cap` waiting calls per client.
    pub fn new(inner: S, concurrency: usize, queue_cap: usize, metrics: Arc<Metrics>) -> Self {
        FairQueue {
            inner,
            concurrency: concurrency.max(1),
            queue_cap: queue_cap.max(1),
            state: Mutex::new(FqState {
                clients: Vec::new(),
                cursor: 0,
                active: 0,
                next_ticket: 0,
                granted: HashSet::new(),
            }),
            wakeup: Condvar::new(),
            metrics,
        }
    }

    /// Grant dispatch slots to tickets while both are available.
    /// Returns how many tickets were newly granted, so the caller can
    /// wake exactly that many parked waiters (`notify_one` per grant)
    /// instead of broadcasting to every parked thread — at high client
    /// counts a `notify_all` per slot release is a thundering herd:
    /// every waiter wakes, contends the state mutex, finds its ticket
    /// ungranted and parks again.
    fn pump(&self, st: &mut FqState) -> usize {
        let mut granted = 0;
        while st.active < self.concurrency {
            match drr_pick(st) {
                Some(ticket) => {
                    st.active += 1;
                    st.granted.insert(ticket);
                    granted += 1;
                }
                None => break,
            }
        }
        granted
    }

    fn release_slot(&self) {
        let mut st = self.state.lock().unwrap();
        st.active -= 1;
        let granted = self.pump(&mut st);
        drop(st);
        for _ in 0..granted {
            self.wakeup.notify_one();
        }
    }
}

/// Returns the dispatch slot (and schedules the next ticket) even if
/// the inner call panics.
struct SlotGuard<'a, S> {
    fq: &'a FairQueue<S>,
}

impl<S> Drop for SlotGuard<'_, S> {
    fn drop(&mut self) {
        self.fq.release_slot();
    }
}

impl<Req, S> Service<Req> for FairQueue<S>
where
    Req: Keyed,
    S: Service<Req>,
{
    type Response = S::Response;

    /// Forwards the inner service's readiness. The fair queue itself
    /// can always queue a new call (per-client bounds are enforced in
    /// `call`, where the client is known), but masking a saturated
    /// backend would turn an outer `LoadShed` into a silent no-op —
    /// propagating `Busy` keeps it usable as a global backstop while
    /// DRR orders what is admitted below saturation.
    fn poll_ready(&self) -> Readiness {
        self.inner.poll_ready()
    }

    fn call(&self, req: Req) -> Result<Self::Response, ServiceError> {
        {
            let mut st = self.state.lock().unwrap();
            let idx = match st.clients.iter().position(|c| c.id == req.client_id()) {
                Some(i) => {
                    st.clients[i].weight = req.weight().max(1);
                    i
                }
                None => {
                    st.clients.push(ClientQueue {
                        id: req.client_id().to_string(),
                        weight: req.weight().max(1),
                        deficit: 0.0,
                        waiting: VecDeque::new(),
                        stats: self.metrics.client(req.client_id()),
                    });
                    st.clients.len() - 1
                }
            };
            if st.clients[idx].waiting.len() >= self.queue_cap {
                self.metrics.fair_shed.fetch_add(1, Ordering::Relaxed);
                st.clients[idx].stats.shed.fetch_add(1, Ordering::Relaxed);
                return Err(ServiceError::Overloaded);
            }
            let ticket = st.next_ticket;
            st.next_ticket += 1;
            st.clients[idx].waiting.push_back(ticket);
            st.clients[idx].stats.queue_depth.fetch_add(1, Ordering::Relaxed);
            // The pump may grant several tickets (ours among them, or
            // other waiters'): wake one parked thread per grant. A
            // condvar cannot target a *specific* waiter, so single
            // wakes need a baton: any thread that wakes without its
            // own grant being ready re-notifies before parking again,
            // and a thread that takes its grant while more grants are
            // outstanding passes the wake along — no grant is ever
            // left with every candidate thread asleep (asserted by
            // the lost-wakeup stress test in tests/fairness.rs).
            let granted = self.pump(&mut st);
            for _ in 0..granted {
                self.wakeup.notify_one();
            }
            while !st.granted.remove(&ticket) {
                st = self.wakeup.wait(st).unwrap();
                if !st.granted.is_empty() && !st.granted.contains(&ticket) {
                    self.wakeup.notify_one();
                }
            }
            if !st.granted.is_empty() {
                self.wakeup.notify_one();
            }
        }
        let _slot = SlotGuard { fq: self };
        self.inner.call(req)
    }
}

/// Builds [`FairQueue`] middlewares; see
/// [`super::stack::Stack::fair_queue`].
#[derive(Clone, Debug)]
pub struct FairQueueLayer {
    concurrency: usize,
    queue_cap: usize,
    metrics: Arc<Metrics>,
}

impl FairQueueLayer {
    /// A layer granting `concurrency` dispatch slots with `queue_cap`
    /// waiting calls per client.
    pub fn new(concurrency: usize, queue_cap: usize, metrics: Arc<Metrics>) -> Self {
        FairQueueLayer { concurrency, queue_cap, metrics }
    }
}

impl<S> Layer<S> for FairQueueLayer {
    type Service = FairQueue<S>;
    fn layer(&self, inner: S) -> Self::Service {
        FairQueue::new(inner, self.concurrency, self.queue_cap, Arc::clone(&self.metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{MockSvc, TestReq};
    use super::*;
    use std::time::Duration;

    fn queue(metrics: &Arc<Metrics>, id: &str, weight: u32, tickets: &[u64]) -> ClientQueue {
        ClientQueue {
            id: id.to_string(),
            weight,
            deficit: 0.0,
            waiting: tickets.iter().copied().collect(),
            stats: metrics.client(id),
        }
    }

    #[test]
    fn drr_respects_weights() {
        let metrics = Arc::new(Metrics::new());
        let mut st = FqState {
            clients: vec![
                queue(&metrics, "a", 1, &[0, 1, 2, 3, 4, 5]),
                queue(&metrics, "b", 2, &[10, 11, 12, 13, 14, 15]),
            ],
            cursor: 0,
            active: 0,
            next_ticket: 100,
            granted: HashSet::new(),
        };
        let picks: Vec<u64> = (0..9).map(|_| drr_pick(&mut st).unwrap()).collect();
        let a_count = picks.iter().filter(|&&t| t < 10).count();
        let b_count = picks.len() - a_count;
        assert_eq!(a_count, 3, "weight-1 client share: {picks:?}");
        assert_eq!(b_count, 6, "weight-2 client share: {picks:?}");
        // Within a client, tickets dispatch FIFO.
        let a_order: Vec<u64> = picks.iter().copied().filter(|&t| t < 10).collect();
        assert_eq!(a_order, vec![0, 1, 2]);
    }

    #[test]
    fn drr_drains_everything_and_empties_rotation() {
        let metrics = Arc::new(Metrics::new());
        let mut st = FqState {
            clients: vec![
                queue(&metrics, "a", 1, &[0, 1]),
                queue(&metrics, "b", 3, &[10]),
                queue(&metrics, "c", 1, &[20, 21, 22]),
            ],
            cursor: 0,
            active: 0,
            next_ticket: 100,
            granted: HashSet::new(),
        };
        let mut seen = Vec::new();
        while let Some(t) = drr_pick(&mut st) {
            seen.push(t);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 10, 20, 21, 22]);
        assert!(st.clients.is_empty(), "drained clients must leave the rotation");
    }

    #[test]
    fn sequential_calls_pass_through() {
        let metrics = Arc::new(Metrics::new());
        let svc = FairQueue::new(MockSvc::instant(), 2, 8, Arc::clone(&metrics));
        for i in 0..6 {
            let id = if i % 2 == 0 { "a" } else { "b" };
            assert!(svc.call(TestReq::client(id)).is_ok());
        }
        assert_eq!(metrics.fair_shed.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.client("a").queue_depth.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.client("b").queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn per_client_overflow_sheds_only_the_flooder() {
        let metrics = Arc::new(Metrics::new());
        // One slot, one waiting ticket per client; a 60ms call holds the
        // slot while we fill and then overflow client a's queue.
        let svc = Arc::new(FairQueue::new(
            MockSvc::with_delay(Duration::from_millis(60)),
            1,
            1,
            Arc::clone(&metrics),
        ));
        std::thread::scope(|scope| {
            let occupant = Arc::clone(&svc);
            scope.spawn(move || occupant.call(TestReq::client("a")).unwrap());
            std::thread::sleep(Duration::from_millis(15));
            let waiter = Arc::clone(&svc);
            scope.spawn(move || waiter.call(TestReq::client("a")).unwrap());
            std::thread::sleep(Duration::from_millis(15));
            // a's queue is full; a bounces, b still has room.
            assert_eq!(svc.call(TestReq::client("a")), Err(ServiceError::Overloaded));
            assert!(svc.call(TestReq::client("b")).is_ok());
        });
        assert_eq!(metrics.fair_shed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.client("a").shed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.client("b").shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn caps_concurrency_into_the_inner_service() {
        let metrics = Arc::new(Metrics::new());
        let svc = Arc::new(FairQueue::new(
            MockSvc::with_delay(Duration::from_millis(10)),
            2,
            16,
            Arc::clone(&metrics),
        ));
        std::thread::scope(|scope| {
            for i in 0..8 {
                let svc = Arc::clone(&svc);
                let id = format!("c{}", i % 4);
                scope.spawn(move || svc.call(TestReq::client(&id)).unwrap());
            }
        });
        assert_eq!(svc.inner.calls.load(std::sync::atomic::Ordering::SeqCst), 8);
        assert!(
            svc.inner.max_in_flight.load(std::sync::atomic::Ordering::SeqCst) <= 2,
            "fair queue leaked concurrency"
        );
    }
}
