//! Deterministic finite automaton substrate for keyword constraints.
//!
//! The Ctrl-G style task (§IV-A) requires every concept keyword to appear
//! somewhere in the generated token sequence. The automaton tracks, per
//! keyword, the longest prefix currently matched (KMP-style) plus the set
//! of keywords already satisfied; a state is accepting when all keywords
//! have been seen. States are interned during BFS construction, which
//! also serves as reachable-state minimization for this state shape
//! (mask + canonical progress vector).
//!
//! The representation is optimized for the HMM-product backward pass in
//! `crate::generate`: per state we store a *default* successor (taken by
//! every token outside the keyword alphabet — the overwhelming majority
//! of the vocabulary) plus a sparse exception list, so the decoder can
//! partition the vocabulary into a handful of classes per state.

use std::collections::HashMap;

/// A compiled keyword-constraint DFA over token ids.
#[derive(Clone, Debug)]
pub struct Dfa {
    /// Vocabulary size the DFA is defined over.
    pub vocab: usize,
    /// The keyword phrases (token-id sequences) being planted.
    pub keywords: Vec<Vec<usize>>,
    n_states: usize,
    start: u32,
    accepting: Vec<bool>,
    default_next: Vec<u32>,
    /// Per state: sorted (token, next_state) for keyword-alphabet tokens.
    exceptions: Vec<Vec<(u32, u32)>>,
}

/// Internal construction state: satisfied mask + per-keyword progress.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct RawState {
    mask: u32,
    progress: Vec<u8>,
}

impl RawState {
    fn canonical(mut self, keywords: &[Vec<usize>]) -> RawState {
        for (k, p) in self.progress.iter_mut().enumerate() {
            if self.mask & (1 << k) != 0 {
                *p = 0; // progress irrelevant once satisfied
            }
            debug_assert!((*p as usize) < keywords[k].len().max(1));
        }
        self
    }
}

/// KMP-style advance: given `matched` chars of `kw` already matched and
/// the next token `t`, return the new number of matched chars.
fn advance(kw: &[usize], matched: usize, t: usize) -> usize {
    let mut m = matched;
    loop {
        if kw[m] == t {
            return m + 1;
        }
        if m == 0 {
            return 0;
        }
        // Fall back to the longest proper border of kw[..m] then retry.
        // Keywords are short (<= 4 tokens), so a direct scan is fine.
        let mut fallback = 0;
        for b in (1..m).rev() {
            if kw[..b] == kw[m - b..m] {
                fallback = b;
                break;
            }
        }
        m = fallback;
    }
}

impl Dfa {
    /// Compile keyword token sequences into a DFA. Empty keywords are
    /// rejected; at most 20 keywords (mask width) are supported.
    pub fn from_keywords(keywords: &[Vec<usize>], vocab: usize) -> Dfa {
        assert!(keywords.len() <= 20, "too many keywords");
        assert!(keywords.iter().all(|k| !k.is_empty()), "empty keyword");
        assert!(
            keywords.iter().flatten().all(|&t| t < vocab),
            "keyword token out of vocabulary"
        );
        let k_n = keywords.len();
        let full_mask: u32 = if k_n == 32 { u32::MAX } else { (1 << k_n) - 1 };

        // Keyword alphabet = candidate exception tokens.
        let mut alphabet: Vec<usize> = keywords.iter().flatten().copied().collect();
        alphabet.sort_unstable();
        alphabet.dedup();

        let mut intern: HashMap<RawState, u32> = HashMap::new();
        let mut states: Vec<RawState> = Vec::new();
        let mut default_next: Vec<u32> = Vec::new();
        let mut exceptions: Vec<Vec<(u32, u32)>> = Vec::new();

        let start_raw = RawState { mask: 0, progress: vec![0; k_n] }.canonical(keywords);
        intern.insert(start_raw.clone(), 0);
        states.push(start_raw);

        let mut frontier = vec![0u32];
        while let Some(sid) = frontier.pop() {
            let state = states[sid as usize].clone();
            // Transition for one token.
            let step = |t: usize, states: &RawState| -> RawState {
                let mut mask = states.mask;
                let mut progress = states.progress.clone();
                for (k, kw) in keywords.iter().enumerate() {
                    if mask & (1 << k) != 0 {
                        continue;
                    }
                    let m = advance(kw, progress[k] as usize, t);
                    if m == kw.len() {
                        mask |= 1 << k;
                        progress[k] = 0;
                    } else {
                        progress[k] = m as u8;
                    }
                }
                RawState { mask, progress }.canonical(keywords)
            };
            // Default: any token outside the alphabet resets progress.
            let default_raw =
                RawState { mask: state.mask, progress: vec![0; k_n] }.canonical(keywords);
            let push_state = |raw: RawState,
                                  intern: &mut HashMap<RawState, u32>,
                                  states: &mut Vec<RawState>,
                                  frontier: &mut Vec<u32>|
             -> u32 {
                if let Some(&id) = intern.get(&raw) {
                    id
                } else {
                    let id = states.len() as u32;
                    intern.insert(raw.clone(), id);
                    states.push(raw);
                    frontier.push(id);
                    id
                }
            };
            let default_id = push_state(default_raw, &mut intern, &mut states, &mut frontier);
            let mut exc = Vec::new();
            for &t in &alphabet {
                let next_raw = step(t, &state);
                let next_id = push_state(next_raw, &mut intern, &mut states, &mut frontier);
                if next_id != default_id {
                    exc.push((t as u32, next_id));
                }
            }
            exc.sort_unstable();
            // default_next / exceptions are indexed by sid; the BFS may
            // discover states out of order, so grow the tables.
            if default_next.len() <= sid as usize {
                default_next.resize(states.len(), u32::MAX);
                exceptions.resize(states.len(), Vec::new());
            }
            default_next[sid as usize] = default_id;
            exceptions[sid as usize] = exc;
        }
        default_next.resize(states.len(), u32::MAX);
        exceptions.resize(states.len(), Vec::new());
        // Every state must have been processed (BFS pops all pushes).
        debug_assert!(default_next.iter().all(|&d| d != u32::MAX));

        let accepting = states.iter().map(|s| s.mask == full_mask).collect();
        Dfa {
            vocab,
            keywords: keywords.to_vec(),
            n_states: states.len(),
            start: 0,
            accepting,
            default_next,
            exceptions,
        }
    }

    /// Number of DFA states.
    pub fn n_states(&self) -> usize {
        self.n_states
    }

    /// The start state.
    pub fn start(&self) -> u32 {
        self.start
    }

    /// Whether `state` is accepting (every keyword matched).
    #[inline]
    pub fn is_accepting(&self, state: u32) -> bool {
        self.accepting[state as usize]
    }

    /// δ(state, token).
    #[inline]
    pub fn next(&self, state: u32, token: usize) -> u32 {
        let exc = &self.exceptions[state as usize];
        match exc.binary_search_by_key(&(token as u32), |&(t, _)| t) {
            Ok(i) => exc[i].1,
            Err(_) => self.default_next[state as usize],
        }
    }

    /// The default successor (token outside every exception).
    #[inline]
    pub fn default_next(&self, state: u32) -> u32 {
        self.default_next[state as usize]
    }

    /// Sparse (token, next) exception list for `state`.
    #[inline]
    pub fn exceptions(&self, state: u32) -> &[(u32, u32)] {
        &self.exceptions[state as usize]
    }

    /// Approximate resident bytes of the compiled automaton (tables +
    /// exception lists + keywords) — used by the byte-budgeted decode
    /// state cache, where the DFA rides along with its table.
    pub fn approx_bytes(&self) -> usize {
        let exceptions: usize = self.exceptions.iter().map(|e| e.len() * 8 + 24).sum();
        let keywords: usize =
            self.keywords.iter().map(|k| k.len() * std::mem::size_of::<usize>() + 24).sum();
        self.accepting.len() + self.default_next.len() * 4 + exceptions + keywords
            + std::mem::size_of::<Self>()
    }

    /// Run the DFA over a token sequence from the start state.
    pub fn run(&self, tokens: &[usize]) -> u32 {
        let mut s = self.start;
        for &t in tokens {
            s = self.next(s, t);
        }
        s
    }

    /// Does the sequence satisfy the constraint (all keywords present)?
    pub fn accepts(&self, tokens: &[usize]) -> bool {
        self.is_accepting(self.run(tokens))
    }
}

/// Reference acceptance check: every keyword appears as a contiguous
/// subsequence. Used by property tests to validate the DFA.
pub fn contains_all_keywords(tokens: &[usize], keywords: &[Vec<usize>]) -> bool {
    keywords.iter().all(|kw| {
        if kw.len() > tokens.len() {
            return false;
        }
        tokens.windows(kw.len()).any(|w| w == kw.as_slice())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;

    #[test]
    fn single_token_keywords() {
        let dfa = Dfa::from_keywords(&[vec![3], vec![7]], 10);
        assert!(!dfa.accepts(&[1, 2, 4]));
        assert!(!dfa.accepts(&[3, 3, 3]));
        assert!(dfa.accepts(&[3, 1, 7]));
        assert!(dfa.accepts(&[7, 3]));
    }

    #[test]
    fn multi_token_keyword_needs_contiguity() {
        let dfa = Dfa::from_keywords(&[vec![1, 2]], 5);
        assert!(dfa.accepts(&[0, 1, 2, 3]));
        assert!(!dfa.accepts(&[1, 3, 2])); // interrupted
        assert!(dfa.accepts(&[1, 1, 2])); // restart on repeated prefix
    }

    #[test]
    fn overlapping_self_prefix() {
        // keyword [1,1,2]: after "1,1,1" progress must stay at 2 (KMP).
        let dfa = Dfa::from_keywords(&[vec![1, 1, 2]], 5);
        assert!(dfa.accepts(&[1, 1, 1, 2]));
        assert!(!dfa.accepts(&[1, 2, 1, 2]));
    }

    #[test]
    fn acceptance_is_monotone() {
        // Once accepting, always accepting.
        let dfa = Dfa::from_keywords(&[vec![2], vec![4, 1]], 6);
        let mut s = dfa.start();
        let seq = [2usize, 4, 1, 0, 5, 3, 2];
        let mut accepted = false;
        for &t in &seq {
            s = dfa.next(s, t);
            if dfa.is_accepting(s) {
                accepted = true;
            }
            if accepted {
                assert!(dfa.is_accepting(s), "acceptance lost");
            }
        }
        assert!(accepted);
    }

    #[test]
    fn dfa_matches_reference_checker() {
        Prop::new(200, 0xD0).run("dfa-vs-reference", |rng, _| {
            let vocab = 8;
            let k_n = rng.range(1, 3);
            let keywords: Vec<Vec<usize>> = (0..k_n)
                .map(|_| {
                    let len = rng.range(1, 3);
                    (0..len).map(|_| rng.below_usize(vocab)).collect()
                })
                .collect();
            let dfa = Dfa::from_keywords(&keywords, vocab);
            let tokens: Vec<usize> =
                (0..rng.range(0, 12)).map(|_| rng.below_usize(vocab)).collect();
            assert_eq!(
                dfa.accepts(&tokens),
                contains_all_keywords(&tokens, &keywords),
                "keywords={keywords:?} tokens={tokens:?}"
            );
        });
    }

    #[test]
    fn exception_lists_are_sparse() {
        let dfa = Dfa::from_keywords(&[vec![3], vec![5, 6]], 1000);
        for s in 0..dfa.n_states() as u32 {
            assert!(dfa.exceptions(s).len() <= 3, "state {s} too many exceptions");
        }
    }

    #[test]
    fn next_consistent_with_exceptions_and_default() {
        let dfa = Dfa::from_keywords(&[vec![2, 3], vec![4]], 50);
        for s in 0..dfa.n_states() as u32 {
            for t in 0..50usize {
                let via_next = dfa.next(s, t);
                let expect = dfa
                    .exceptions(s)
                    .iter()
                    .find(|&&(tok, _)| tok == t as u32)
                    .map(|&(_, n)| n)
                    .unwrap_or(dfa.default_next(s));
                assert_eq!(via_next, expect);
            }
        }
    }

    #[test]
    fn state_count_is_reasonable() {
        // 3 single-token keywords: states = subsets of satisfied = 8.
        let dfa = Dfa::from_keywords(&[vec![1], vec![2], vec![3]], 10);
        assert_eq!(dfa.n_states(), 8);
    }
}
