//! Robustness and failure-injection integration tests: fuzzed JSON,
//! quantized-model inference invariants, coordinator under stress.

use normq::data::Corpus;
use normq::hmm::forward::{forward, log_likelihood};
use normq::hmm::Hmm;
use normq::quant::Method;
use normq::util::json::Json;
use normq::util::proptest::Prop;
use normq::util::rng::Rng;

#[test]
fn json_fuzz_never_panics_and_roundtrips_valid_docs() {
    // Random bytes must parse-or-error, never panic; random *valid*
    // documents must round-trip exactly.
    Prop::new(300, 0xFEED).run("json-fuzz", |rng, case| {
        if case % 2 == 0 {
            // garbage bytes (printable-ish to hit the parser paths)
            let len = rng.range(0, 40);
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32;
                    c as char
                })
                .collect();
            let _ = Json::parse(&s); // must not panic
        } else {
            // random valid document
            fn gen_value(rng: &mut Rng, depth: usize) -> Json {
                match if depth > 2 { rng.below(4) } else { rng.below(6) } {
                    0 => Json::Null,
                    1 => Json::Bool(rng.below(2) == 0),
                    2 => Json::Num((rng.f64() - 0.5) * 1e6),
                    3 => Json::Str(format!("s{}\n\"x\\{}", rng.below(100), rng.below(10))),
                    4 => Json::Arr((0..rng.range(0, 4)).map(|_| gen_value(rng, depth + 1)).collect()),
                    _ => Json::Obj(
                        (0..rng.range(0, 4))
                            .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                            .collect(),
                    ),
                }
            }
            let v = gen_value(rng, 0);
            let text = v.to_string();
            let parsed = Json::parse(&text).expect("serialized JSON must parse");
            // Numbers survive to f64 precision; compare re-serialization.
            assert_eq!(parsed.to_string(), text);
        }
    });
}

#[test]
fn quantized_models_never_produce_nan_likelihoods() {
    Prop::new(40, 0xBEEF).run("quantized-ll-finite-or-neginf", |rng, _| {
        let h = rng.range(2, 10);
        let v = rng.range(4, 30);
        let hmm = Hmm::random(h, v, 0.1, 0.05, rng);
        let method = match rng.below(4) {
            0 => Method::NormQ { bits: [2u32, 3, 8][rng.below_usize(3)] },
            1 => Method::Fixed { bits: 3 },
            2 => Method::Integer { bits: 4 },
            _ => Method::Prune { ratio: 0.95, renorm: rng.below(2) == 0 },
        };
        let q = method.apply(&hmm);
        let tokens: Vec<usize> = (0..rng.range(1, 12)).map(|_| rng.below_usize(v)).collect();
        let ll = log_likelihood(&q, &tokens);
        assert!(!ll.is_nan(), "{} produced NaN", method.label());
        // Filtering distributions stay normalized (or uniform-reset).
        let fwd = forward(&q, &tokens);
        for a in &fwd.alphas {
            let s: f64 = a.iter().map(|&x| x as f64).sum();
            assert!((s - 1.0).abs() < 1e-3, "{}: alpha sum {s}", method.label());
            assert!(a.iter().all(|x| !x.is_nan()));
        }
    });
}

#[test]
fn normq_likelihood_converges_to_fp32_with_bits() {
    // KL-style sanity: LLD(normq_b) → LLD(fp32) monotonically-ish in b.
    let mut rng = Rng::seeded(0xCAFE);
    let hmm = Hmm::random(12, 40, 0.1, 0.05, &mut rng);
    let seqs: Vec<Vec<usize>> = (0..30).map(|_| hmm.sample(10, &mut rng)).collect();
    let lld = |m: &Hmm| -> f64 {
        seqs.iter().map(|s| log_likelihood(m, s)).sum::<f64>() / seqs.len() as f64
    };
    let base = lld(&hmm);
    let err_at = |bits: u32| (lld(&Method::NormQ { bits }.apply(&hmm)) - base).abs();
    let (e3, e8, e12) = (err_at(3), err_at(8), err_at(12));
    assert!(e8 < e3, "e8={e8} e3={e3}");
    assert!(e12 < e8 + 0.1, "e12={e12} e8={e8}");
    assert!(e12 < 0.2, "12-bit Norm-Q should be near-exact, err={e12}");
}

#[test]
fn coordinator_survives_burst_load_with_mixed_concepts() {
    use normq::coordinator::{Server, ServerConfig};
    use normq::generate::DecodeConfig;
    use std::sync::Arc;

    let corpus = Corpus::small(4242);
    let data = corpus.sample_token_corpus(400, 1);
    let lm = Arc::new(normq::lm::NgramLm::train(&data, corpus.vocab.len()));
    let mut rng = Rng::seeded(2);
    let mut hmm = Hmm::random(8, corpus.vocab.len(), 0.5, 0.5, &mut rng);
    for _ in 0..3 {
        hmm = normq::hmm::em::em_step(&hmm, &data, 4, 1e-9).0;
    }
    let cfg = ServerConfig {
        workers: 4,
        queue_capacity: 512,
        decode: DecodeConfig { beam: 3, max_tokens: 10, ..Default::default() },
        ..Default::default()
    };
    let server = Server::start(lm, hmm, corpus.clone(), cfg);
    // Burst: 120 requests over 12 distinct concept sets.
    let mut rxs = Vec::new();
    for i in 0..120 {
        let c = vec![corpus.lexicon.nouns[i % 12].clone()];
        if let Ok(rx) = server.submit(c) {
            rxs.push(rx);
        }
    }
    let mut completed = 0;
    for rx in rxs {
        if rx.recv_timeout(std::time::Duration::from_secs(60)).is_ok() {
            completed += 1;
        }
    }
    assert!(completed >= 100, "only {completed}/120 completed");
    // Table cache: at most 12 misses despite 120 requests.
    let misses = server
        .metrics()
        .table_cache_misses
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(misses <= 12, "cache misses {misses} > concept sets");
    server.shutdown();
}

/// Build-pool failure isolation, wired the way the coordinator wires
/// it: a panicking build runs its `on_panic` cleanup — which aborts
/// only *its own* pending cache entry and answers that entry's waiters
/// with an error — while the pool worker survives and keeps building
/// other groups' tables.
#[test]
fn panicking_build_poisons_only_its_cache_entry_not_the_pool() {
    use normq::coordinator::buildpool::{BuildJob, BuildPool};
    use normq::coordinator::cache::{ByteSized, Lookup, LruCache};
    use std::sync::mpsc::channel;
    use std::sync::{Arc, Mutex};

    struct Table(u32);
    impl ByteSized for Table {
        fn bytes(&self) -> usize {
            64
        }
    }
    // Waiters are reply channels, the pending handle is unit — the
    // same state machine the coordinator instantiates with Requests
    // and BuildControl.
    type Cache = LruCache<Table, std::sync::mpsc::Sender<Result<u32, String>>, ()>;

    let cache = Arc::new(Mutex::new(Cache::new(1 << 20)));
    let pool = BuildPool::new(1);

    // Two cold groups resolve to two pending entries, each with one
    // waiter; "bad" panics mid-build, "good" builds normally.
    let (bad_tx, bad_rx) = channel();
    let (good_tx, good_rx) = channel();
    for (key, tx) in [("bad", bad_tx), ("good", good_tx)] {
        let started = cache.lock().unwrap().lookup(key, vec![tx], || ((), 64));
        assert!(matches!(started, Lookup::Started(())));
    }

    let panic_cache = Arc::clone(&cache);
    assert!(pool.spawn(BuildJob::new(
        || panic!("injected model panic"),
        move || {
            // The coordinator's on_panic: abort this entry, answer its
            // waiters with an error, release their slots.
            let waiters = panic_cache.lock().unwrap().abort("bad");
            for w in waiters {
                let _ = w.send(Err("table build failed".into()));
            }
        },
    )));
    let good_cache = Arc::clone(&cache);
    assert!(pool.spawn(BuildJob::new(
        move || {
            let (value, waiters) = good_cache.lock().unwrap().complete("good", Table(7));
            for w in waiters {
                let _ = w.send(Ok(value.0));
            }
        },
        || panic!("the good build must not fail"),
    )));

    // The bad group's waiter got an error response…
    let bad = bad_rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
    assert!(bad.is_err(), "waiters of a panicked build must see an error");
    // …and the same (single-threaded) pool still built the good group.
    let good = good_rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
    assert_eq!(good, Ok(7));

    let mut c = cache.lock().unwrap();
    assert_eq!(c.pending(), 0, "no pending entry may leak");
    assert!(c.get("bad").is_none(), "the panicked entry is poisoned, not cached");
    assert_eq!(c.get("good").unwrap().0, 7);
    drop(c);
    pool.shutdown();
}

#[test]
fn decode_handles_unsatisfiable_budget_gracefully() {
    // A 4-keyword constraint with a 2-token budget is unsatisfiable; the
    // decoder must terminate and report satisfied=false.
    let corpus = Corpus::small(777);
    let data = corpus.sample_token_corpus(200, 1);
    let lm = normq::lm::NgramLm::train(&data, corpus.vocab.len());
    let mut rng = Rng::seeded(3);
    let hmm = Hmm::random(6, corpus.vocab.len(), 0.5, 0.5, &mut rng);
    let keywords: Vec<Vec<usize>> = (0..4)
        .map(|i| vec![corpus.vocab.id(&corpus.lexicon.nouns[i])])
        .collect();
    let dfa = normq::dfa::Dfa::from_keywords(&keywords, corpus.vocab.len());
    let cfg = normq::generate::DecodeConfig { beam: 4, max_tokens: 2, ..Default::default() };
    let gen = normq::generate::decode(&lm, &hmm, &dfa, &cfg);
    assert!(!gen.satisfied);
    assert!(gen.tokens.len() <= 2);
}
