"""Layer-1 Pallas kernel: the fused HMM forward step.

The decode hot spot is `alpha' = normalize(alpha * emit[:, x]) @ trans` —
a (B×H)·(H×H) MatMul fed by an elementwise gate and a row reduction. On
GPU the paper's motivation is bandwidth (§I); the TPU mapping
(DESIGN.md §Hardware-Adaptation) batches beams so the MXU sees a real
matmul, keeps the gate + normalization in VPU lanes inside the same
kernel (no HBM round trip between them), and tiles `trans` HBM→VMEM in
(BH, HT)-blocks with the grid iterating over output tiles.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(alpha_ref, emit_col_ref, trans_ref, out_ref, scale_ref, *, h_total):
    """Grid dim 0 walks output tiles of H. The gate + normalization are
    recomputed per tile (cheap VPU work) so each grid step is independent
    and `trans` streams through VMEM one (H, HT) block at a time."""
    alpha = alpha_ref[...]          # [B, H]  (full rows resident in VMEM)
    emit_col = emit_col_ref[...]    # [B, H]
    weighted = alpha * emit_col
    scale = jnp.sum(weighted, axis=-1, keepdims=True)  # [B, 1]
    uniform = jnp.full_like(weighted, 1.0 / h_total)
    safe = jnp.where(scale > 0, weighted / jnp.where(scale > 0, scale, 1.0), uniform)
    # [B, H] @ [H, HT] -> [B, HT] on the MXU.
    out_ref[...] = safe @ trans_ref[...]
    scale_ref[...] = scale[:, 0]


@functools.partial(jax.jit, static_argnames=("tile",))
def forward_step(alpha, emit_col, trans, tile: int = 128):
    """Pallas-fused forward step; same contract as ref.forward_step."""
    b, h = alpha.shape
    assert trans.shape == (h, h)
    tile = min(tile, h)
    # Grid over output-column tiles; pad H up to a tile multiple.
    pad = (-h) % tile
    if pad:
        trans_p = jnp.pad(trans, ((0, 0), (0, pad)))
    else:
        trans_p = trans
    h_out = h + pad
    grid = (h_out // tile,)
    nxt, scale = pl.pallas_call(
        functools.partial(_kernel, h_total=h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, h), lambda j: (0, 0)),      # alpha: resident
            pl.BlockSpec((b, h), lambda j: (0, 0)),      # emit_col: resident
            pl.BlockSpec((h, tile), lambda j: (0, j)),   # trans: streamed
        ],
        out_specs=[
            pl.BlockSpec((b, tile), lambda j: (0, j)),
            pl.BlockSpec((b,), lambda j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h_out), alpha.dtype),
            jax.ShapeDtypeStruct((b,), alpha.dtype),
        ],
        interpret=True,
    )(alpha, emit_col, trans_p)
    return nxt[:, :h], scale
