//! `AdaptiveShed`: an in-flight limit derived from observed service
//! time (Little's law) instead of a hand-tuned `queue_capacity`.
//!
//! The static [`super::shed::LoadShed`] keys off the coordinator's
//! fixed queue capacity, which goes stale whenever per-request decode
//! cost shifts — more HMM states, a different quantization level, or a
//! colder table cache all change how much queueing a latency budget
//! can afford. This layer closes the loop: it tracks an EWMA of the
//! inner service's observed **service time** `S` — call latency minus
//! the response's self-reported queue wait ([`super::Queued`]), so
//! time spent parked behind a queue or a cold table build is not
//! mistaken for work — and admits at most
//!
//! ```text
//! limit = workers × budget / S        (Little's law: L = λ·W)
//! ```
//!
//! in-flight calls, so the expected time-in-system of an admitted
//! request stays within `budget`. Excess calls are rejected with
//! `Err(Overloaded)` (counted in `Metrics::adaptive_shed`, attributed
//! per client); the current limit is exported through the
//! `Metrics::adaptive_limit` gauge. As the backend speeds up the limit
//! rises and as it slows the limit tightens — no knob to re-tune.
//!
//! One coordinator-specific correction: requests parked as waiters on
//! a pending constraint-table build (the coordinator's
//! `Metrics::build_waiting` gauge) are admitted but are *not* decode
//! work — they occupy no worker. Counting them against the
//! Little's-law limit would read a cold-build storm as decode
//! saturation and shed warm traffic that the workers could absorb, so
//! the layer discounts the gauge from its in-flight count. The
//! discount is deliberately approximate: with `Hedge` composed below
//! this layer, a hedged call can park *two* coordinator requests on
//! one build, over-counting the discount — the error is in the
//! admit-more direction during a build storm, never toward shedding
//! warm traffic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;

use super::{Keyed, Layer, Queued, Readiness, Service, ServiceError};

/// Default cap on the derived limit, generous enough to be invisible
/// until the first latency observations arrive.
const DEFAULT_MAX_LIMIT: usize = 1024;

/// EWMA smoothing factor: each observation moves the estimate 20% of
/// the way toward itself — stable under decode-time noise, yet a
/// sustained shift re-converges within a dozen requests.
const EWMA_ALPHA: f64 = 0.2;

/// Decrements the in-flight gauge even if the inner call panics.
struct InFlightGuard<'a>(&'a AtomicU64);

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Latency-adaptive load shedding; see the [module docs](self).
///
/// ```
/// use std::sync::Arc;
/// use std::time::Duration;
/// use normq::coordinator::metrics::Metrics;
/// use normq::coordinator::ServeRequest;
/// use normq::service::{Echo, Service, Stack};
///
/// let metrics = Arc::new(Metrics::new());
/// // Keep time-in-system under 100ms given a 4-worker backend.
/// let svc = Stack::new()
///     .adaptive_shed(Duration::from_millis(100), 4, Arc::clone(&metrics))
///     .service(Echo::instant());
/// assert!(svc.call(ServeRequest::new(vec!["tree".into()])).is_ok());
/// assert!(metrics.adaptive_limit.load(std::sync::atomic::Ordering::Relaxed) >= 1);
/// ```
pub struct AdaptiveShed<S> {
    inner: S,
    /// Target time-in-system (queue wait + service) for admitted calls.
    budget: Duration,
    /// Parallelism hint: how many calls the backend completes
    /// concurrently (the coordinator's decode-worker count).
    workers: usize,
    min_limit: usize,
    max_limit: usize,
    in_flight: AtomicU64,
    /// EWMA of observed call latency in seconds; `None` until the
    /// first completion (the limit stays at `max_limit` until then).
    ewma: Mutex<Option<f64>>,
    metrics: Arc<Metrics>,
}

impl<S> AdaptiveShed<S> {
    /// Wrap `inner` with a latency-derived in-flight limit targeting
    /// `budget` time-in-system on a `workers`-wide backend.
    pub fn new(inner: S, budget: Duration, workers: usize, metrics: Arc<Metrics>) -> Self {
        AdaptiveShed {
            inner,
            budget,
            workers: workers.max(1),
            min_limit: 1,
            max_limit: DEFAULT_MAX_LIMIT,
            in_flight: AtomicU64::new(0),
            ewma: Mutex::new(None),
            metrics,
        }
    }

    /// Clamp the derived limit to `[min, max]` (e.g. to guarantee a
    /// floor of one call per worker regardless of a latency spike).
    pub fn with_limits(mut self, min: usize, max: usize) -> Self {
        self.min_limit = min.max(1);
        self.max_limit = max.max(self.min_limit);
        self
    }

    /// The in-flight limit implied by the current latency estimate.
    pub fn current_limit(&self) -> usize {
        match *self.ewma.lock().unwrap() {
            Some(s) if s > 0.0 => {
                let l = (self.workers as f64 * self.budget.as_secs_f64() / s) as usize;
                l.clamp(self.min_limit, self.max_limit)
            }
            // No (usable) observation yet: admit optimistically and let
            // the first completions pull the limit down.
            _ => self.max_limit,
        }
    }

    fn observe(&self, secs: f64) {
        let mut e = self.ewma.lock().unwrap();
        *e = Some(match *e {
            None => secs,
            Some(prev) => prev + EWMA_ALPHA * (secs - prev),
        });
    }

    /// Admitted calls that count against the limit: everything in
    /// flight except requests parked on a pending table build (they
    /// hold no decode worker; see the [module docs](self)).
    fn decode_in_flight(&self) -> u64 {
        self.in_flight
            .load(Ordering::SeqCst)
            .saturating_sub(self.metrics.build_waiting.load(Ordering::Relaxed))
    }
}

impl<Req, S> Service<Req> for AdaptiveShed<S>
where
    Req: Keyed,
    S: Service<Req>,
    S::Response: Queued,
{
    type Response = S::Response;

    fn poll_ready(&self) -> Readiness {
        if self.decode_in_flight() >= self.current_limit() as u64 {
            Readiness::Busy
        } else {
            self.inner.poll_ready()
        }
    }

    fn call(&self, req: Req) -> Result<Self::Response, ServiceError> {
        let limit = self.current_limit();
        self.metrics.adaptive_limit.store(limit as u64, Ordering::Relaxed);
        // Admission is decided from the fetch_add's *returned* count:
        // at the boundary, concurrent arrivals each see a distinct
        // prior value, so exactly `limit` of them win — re-reading the
        // shared counter here would let simultaneous arrivals shed
        // each other below capacity.
        let prev = self.in_flight.fetch_add(1, Ordering::SeqCst);
        let guard = InFlightGuard(&self.in_flight);
        let waiting = self.metrics.build_waiting.load(Ordering::Relaxed);
        if prev.saturating_sub(waiting) >= limit as u64 {
            drop(guard);
            self.metrics.adaptive_shed.fetch_add(1, Ordering::Relaxed);
            self.metrics
                .client(req.client_id())
                .shed
                .fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::Overloaded);
        }
        let t0 = Instant::now();
        let out = self.inner.call(req);
        // Feed the estimator from calls that did real work. Instant
        // errors (an inner layer bouncing) would drag the EWMA toward
        // zero and inflate the limit right when the system is refusing
        // work. Queue wait (including time parked on a cold table
        // build) is subtracted: Little's law wants *service* time, and
        // a 2s cold build observed as service time would collapse the
        // limit and shed warm traffic the workers could absorb.
        match &out {
            Ok(resp) => {
                let service = t0.elapsed().saturating_sub(resp.queue_wait());
                self.observe(service.as_secs_f64());
            }
            Err(ServiceError::DeadlineExceeded) => {
                // A timed-out call carries no response to report its
                // queue share. Its (deadline-bounded) latency is real
                // overload signal when the decode plane is what's
                // slow — but during a cold-build storm it is mostly
                // parked wait, so skip the sample while any request
                // sits on a pending build rather than let that wait
                // masquerade as service time.
                if self.metrics.build_waiting.load(Ordering::Relaxed) == 0 {
                    self.observe(t0.elapsed().as_secs_f64());
                }
            }
            Err(_) => {}
        }
        out
    }
}

/// Builds [`AdaptiveShed`] middlewares; see
/// [`super::stack::Stack::adaptive_shed`].
#[derive(Clone, Debug)]
pub struct AdaptiveShedLayer {
    budget: Duration,
    workers: usize,
    metrics: Arc<Metrics>,
}

impl AdaptiveShedLayer {
    /// A layer targeting `budget` time-in-system on a `workers`-wide
    /// backend.
    pub fn new(budget: Duration, workers: usize, metrics: Arc<Metrics>) -> Self {
        AdaptiveShedLayer { budget, workers, metrics }
    }
}

impl<S> Layer<S> for AdaptiveShedLayer {
    type Service = AdaptiveShed<S>;
    fn layer(&self, inner: S) -> Self::Service {
        AdaptiveShed::new(inner, self.budget, self.workers, Arc::clone(&self.metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{MockSvc, TestReq};
    use super::*;

    #[test]
    fn passes_while_under_the_limit() {
        let metrics = Arc::new(Metrics::new());
        let svc = AdaptiveShed::new(
            MockSvc::instant(),
            Duration::from_millis(100),
            4,
            Arc::clone(&metrics),
        );
        for _ in 0..8 {
            assert!(svc.call(TestReq::default()).is_ok());
        }
        assert_eq!(metrics.adaptive_shed.load(Ordering::Relaxed), 0);
        assert!(metrics.adaptive_limit.load(Ordering::Relaxed) >= 1);
        assert_eq!(svc.in_flight.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn sheds_once_the_derived_limit_is_hit() {
        let metrics = Arc::new(Metrics::new());
        // 50ms service time against a 10ms budget on one worker: after
        // the first observation the limit collapses to the floor of 1.
        let svc = Arc::new(AdaptiveShed::new(
            MockSvc::with_delay(Duration::from_millis(50)),
            Duration::from_millis(10),
            1,
            Arc::clone(&metrics),
        ));
        svc.call(TestReq::client("warm")).unwrap();
        assert_eq!(svc.current_limit(), 1);
        std::thread::scope(|scope| {
            let occupant = Arc::clone(&svc);
            scope.spawn(move || occupant.call(TestReq::client("heavy")).unwrap());
            std::thread::sleep(Duration::from_millis(15));
            assert_eq!(svc.poll_ready(), Readiness::Busy);
            assert_eq!(
                svc.call(TestReq::client("heavy")),
                Err(ServiceError::Overloaded)
            );
        });
        assert_eq!(metrics.adaptive_shed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.client("heavy").shed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn limit_tracks_littles_law() {
        let metrics = Arc::new(Metrics::new());
        // S ≈ 20ms, workers = 4, budget = 80ms → limit ≈ 4·80/20 = 16.
        let svc = AdaptiveShed::new(
            MockSvc::with_delay(Duration::from_millis(20)),
            Duration::from_millis(80),
            4,
            Arc::clone(&metrics),
        );
        for _ in 0..10 {
            svc.call(TestReq::default()).unwrap();
        }
        let limit = svc.current_limit();
        assert!(
            (6..=24).contains(&limit),
            "limit did not converge near 16: {limit}"
        );
    }

    #[test]
    fn build_waiting_requests_are_not_counted_as_decode_in_flight() {
        let metrics = Arc::new(Metrics::new());
        // 50ms service against a 10ms budget on one worker: the limit
        // collapses to the floor of 1 after the first observation.
        let svc = Arc::new(AdaptiveShed::new(
            MockSvc::with_delay(Duration::from_millis(50)),
            Duration::from_millis(10),
            1,
            Arc::clone(&metrics),
        ));
        svc.call(TestReq::client("warm")).unwrap();
        assert_eq!(svc.current_limit(), 1);
        // Two of the occupants are parked on a pending table build
        // (the coordinator's gauge): they must not consume the limit.
        metrics.build_waiting.store(2, Ordering::Relaxed);
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let occupant = Arc::clone(&svc);
                scope.spawn(move || occupant.call(TestReq::client("parked")).unwrap());
            }
            std::thread::sleep(Duration::from_millis(15));
            // in_flight = 2, build_waiting = 2 → decode in-flight 0:
            // the layer still admits (and still reports Ready).
            assert_eq!(svc.poll_ready(), Readiness::Ready);
            assert!(svc.call(TestReq::client("live")).is_ok());
        });
        metrics.build_waiting.store(0, Ordering::Relaxed);
        assert_eq!(metrics.adaptive_shed.load(Ordering::Relaxed), 0);
        assert_eq!(svc.in_flight.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn queue_wait_is_not_observed_as_service_time() {
        // A response reporting that 45 of its 50ms were spent queued
        // (e.g. parked on a cold table build): the EWMA must learn
        // S ≈ 5ms, not 50ms — otherwise one cold build collapses the
        // limit and sheds warm traffic.
        struct QueuedResp;
        impl Queued for QueuedResp {
            fn queue_wait(&self) -> Duration {
                Duration::from_millis(45)
            }
        }
        struct QueuedSvc;
        impl Service<TestReq> for QueuedSvc {
            type Response = QueuedResp;
            fn poll_ready(&self) -> Readiness {
                Readiness::Ready
            }
            fn call(&self, _req: TestReq) -> Result<QueuedResp, ServiceError> {
                std::thread::sleep(Duration::from_millis(50));
                Ok(QueuedResp)
            }
        }
        let metrics = Arc::new(Metrics::new());
        let svc = AdaptiveShed::new(QueuedSvc, Duration::from_millis(20), 1, metrics);
        svc.call(TestReq::default()).unwrap();
        // Raw latency (50ms) against the 20ms budget would clamp the
        // limit to the floor of 1; the queue-corrected S (~5ms) keeps
        // real headroom.
        let limit = svc.current_limit();
        assert!(limit >= 2, "queue wait leaked into the service estimate: limit {limit}");
    }

    #[test]
    fn instant_errors_do_not_inflate_the_limit() {
        let metrics = Arc::new(Metrics::new());
        let mut inner = MockSvc::with_delay(Duration::from_millis(20));
        inner.fail_call = Some(1);
        let svc = AdaptiveShed::new(
            inner,
            Duration::from_millis(40),
            1,
            Arc::clone(&metrics),
        );
        svc.call(TestReq::default()).unwrap(); // 20ms observation
        let before = svc.current_limit();
        let _ = svc.call(TestReq::default()); // instant Overloaded from inner
        assert_eq!(svc.current_limit(), before, "error latency must not be observed");
    }
}
