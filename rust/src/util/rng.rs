//! Deterministic pseudo-random number generation.
//!
//! The offline crate set does not include `rand`, so we own a small,
//! well-tested PRNG stack: SplitMix64 for seeding and xoshiro256** as the
//! workhorse generator. Both are public-domain algorithms (Blackman &
//! Vigna). Everything in this repository that needs randomness threads a
//! `Rng` explicitly — there is no global generator — so every experiment
//! is reproducible from its seed.

/// SplitMix64: used to expand a single `u64` seed into the 256-bit
/// xoshiro state. Also usable standalone for cheap hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::seeded(self.next_u64())
    }

    /// The next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish method
    /// with a widening multiply; unbiased via rejection on the low word.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Standard normal via Box-Muller (cached spare not kept: simplicity).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-12 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Returns `weights.len() - 1` if rounding leaves residual mass.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        debug_assert!(!weights.is_empty());
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        if total <= 0.0 {
            // Degenerate distribution: fall back to uniform.
            return self.below_usize(weights.len());
        }
        let mut target = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w as f64;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample a Dirichlet(alpha * 1) vector of dimension `n` using the
    /// Gamma-ratio construction (Marsaglia-Tsang for shape >= 1, boosted
    /// for shape < 1). Used to synthesize probability rows at paper scale.
    pub fn dirichlet_symmetric(&mut self, n: usize, alpha: f64) -> Vec<f32> {
        let mut out = vec![0f32; n];
        let mut sum = 0f64;
        for slot in out.iter_mut() {
            let g = self.gamma(alpha);
            *slot = g as f32;
            sum += g;
        }
        if sum <= 0.0 {
            let v = 1.0 / n as f32;
            for slot in out.iter_mut() {
                *slot = v;
            }
        } else {
            let inv = (1.0 / sum) as f32;
            for slot in out.iter_mut() {
                *slot *= inv;
            }
        }
        out
    }

    /// Gamma(shape, 1) sampler (Marsaglia & Tsang 2000).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u = loop {
                let u = self.f64();
                if u > 1e-300 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v3;
            }
            if u > 1e-300 && u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::seeded(4);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_mean_is_uniformish() {
        let mut r = Rng::seeded(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.below(1000) as f64).sum::<f64>() / n as f64;
        assert!((mean - 499.5).abs() < 5.0, "mean={mean}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::seeded(6);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seeded(8);
        for &alpha in &[0.05, 0.5, 1.0, 5.0] {
            let v = r.dirichlet_symmetric(64, alpha);
            let s: f64 = v.iter().map(|&x| x as f64).sum();
            assert!((s - 1.0).abs() < 1e-4, "alpha={alpha} sum={s}");
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn sparse_dirichlet_is_sparser() {
        let mut r = Rng::seeded(9);
        let sparse = r.dirichlet_symmetric(256, 0.02);
        let dense = r.dirichlet_symmetric(256, 5.0);
        let small = |v: &[f32]| v.iter().filter(|&&x| x < 1e-5).count();
        assert!(small(&sparse) > small(&dense));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(10);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
