//! Keeps `docs/METRICS.md` honest: the glossary's table rows and the
//! key set `Metrics::summary` actually emits must match exactly, in
//! both directions. Adding a counter without documenting it — or
//! documenting a counter that no longer exists — fails this test.

use std::collections::BTreeSet;

use normq::coordinator::metrics::Metrics;

/// Parse the keys out of one `summary()` line. Tokens are
/// whitespace-separated `key=value` pairs; a token *without* `=`
/// (`cache`, `spill`, `latency`) is a prefix that attaches to the next
/// key, giving the compound keys `cache h/m`, `spill h/w` and
/// `latency p50`.
fn summary_keys(summary: &str) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    let mut prefix: Option<&str> = None;
    for token in summary.split_whitespace() {
        match token.split_once('=') {
            Some((key, _)) => {
                let full = match prefix.take() {
                    Some(p) => format!("{p} {key}"),
                    None => key.to_string(),
                };
                keys.insert(full);
            }
            None => prefix = Some(token),
        }
    }
    keys
}

/// The backticked first column of every glossary table row in
/// `docs/METRICS.md` (lines shaped `| \`key\` | ... |`).
fn glossary_keys(doc: &str) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    for line in doc.lines() {
        let Some(rest) = line.strip_prefix("| `") else { continue };
        let Some((key, _)) = rest.split_once('`') else { continue };
        keys.insert(key.to_string());
    }
    keys
}

#[test]
fn glossary_matches_the_summary_key_set() {
    let metrics = Metrics::new();
    // Record one latency sample so the summary renders the quantile
    // block instead of "latency n/a".
    metrics.record_latency(0.010, 0.001);
    let emitted = summary_keys(&metrics.summary());
    assert!(
        emitted.contains("submitted") && emitted.contains("latency p50"),
        "summary parser is broken: {emitted:?}"
    );

    let doc = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/METRICS.md"));
    let documented = glossary_keys(doc);

    let undocumented: Vec<_> = emitted.difference(&documented).collect();
    assert!(
        undocumented.is_empty(),
        "summary keys missing from docs/METRICS.md: {undocumented:?}"
    );
    let stale: Vec<_> = documented.difference(&emitted).collect();
    assert!(
        stale.is_empty(),
        "docs/METRICS.md documents keys the summary does not emit: {stale:?}"
    );
}

/// The per-client wait split lives in `Metrics::client_summary()`
/// (not the global summary line), so its keys are documented in the
/// glossary's prose rather than the table. Keep that prose honest the
/// same way: every wait key the client line emits must be named in
/// `docs/METRICS.md`, and the doc must not name a wait bucket the
/// line no longer renders.
#[test]
fn client_wait_split_keys_are_documented_in_prose() {
    let metrics = Metrics::new();
    metrics.client("tenant").record_latency(0.012);
    metrics.client("tenant").record_waits(0.001, 0.008, 0.003);
    let line = metrics.client_summary();
    let doc = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/METRICS.md"));
    for key in ["q_p99", "b_p99", "d_p99"] {
        assert!(line.contains(&format!("{key}=")), "client summary lost {key}: {line}");
        assert!(doc.contains(&format!("`{key}`")), "docs/METRICS.md prose must name {key}");
    }
}
