//! Serving metrics registry: atomic counters + bounded latency reservoirs.
//!
//! Counters cover the whole admission path: intake (`submitted`,
//! `rejected`), the middleware stack (`shed`, `timed_out`, `hedged`,
//! `hedge_wins`, `quota_denied`, `fair_shed`, `adaptive_shed` — see
//! [`crate::service`]), and the decode plane (`completed`,
//! `satisfied`, table-cache hits/misses). Latency and queue-wait
//! samples go through fixed-size reservoir sampling (Vitter's
//! Algorithm R) so memory stays bounded under sustained traffic while
//! quantiles remain an unbiased estimate of the full stream.
//!
//! Per-client attribution lives in [`ClientStats`], handed out by
//! [`Metrics::client`]: the fairness layers charge sheds, quota
//! denials and queue depth to the client that caused them, so a
//! greedy client's overload shows up in *its* row of
//! [`Metrics::client_summary`] rather than as anonymous global load.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::util::rng::Rng;
use crate::util::timer::Stats;

/// Default reservoir capacity: large enough for stable p99 estimates,
/// small enough (~32 KB per reservoir) to hold for days of traffic.
pub const RESERVOIR_CAP: usize = 4096;

/// Fixed-size uniform sample of an unbounded stream (Algorithm R).
/// After `seen` pushes every element has probability `cap/seen` of
/// being in the sample, so quantiles computed over the sample are
/// unbiased estimates of the stream quantiles.
#[derive(Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: Rng,
}

impl Reservoir {
    /// An empty reservoir retaining at most `cap` samples (min 1).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Reservoir {
            cap,
            seen: 0,
            samples: Vec::with_capacity(cap.min(1024)),
            rng: Rng::seeded(0x5EED_CAFE),
        }
    }

    /// Observe one value; retained with probability `cap/seen`.
    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            let j = self.rng.below(self.seen) as usize;
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }

    /// Total values observed (not the sample size).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// The retained sample (an unbiased subset of the stream).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// True before the first observation.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Per-client counter block, created on first touch by
/// [`Metrics::client`]. All counters are charged by the layer that
/// made the decision: the coordinator (submitted/completed/shed at
/// intake), `Quota` (quota_denied), `FairQueue` (shed on overflow,
/// queue_depth while waiting), `AdaptiveShed` and `LoadShed` (shed).
#[derive(Debug, Default)]
pub struct ClientStats {
    /// Requests this client submitted to the coordinator.
    pub submitted: AtomicU64,
    /// Requests answered by a decode worker (including timed-out ones).
    pub completed: AtomicU64,
    /// Admission rejections charged to this client (fair-queue
    /// overflow, adaptive/static shed, or a full intake queue).
    pub shed: AtomicU64,
    /// Rejections by the `Quota` middleware (bucket + overflow empty).
    pub quota_denied: AtomicU64,
    /// Calls currently waiting in this client's fair queue (gauge).
    pub queue_depth: AtomicU64,
}

impl ClientStats {
    /// One-line rendering used by [`Metrics::client_summary`].
    fn summary(&self) -> String {
        format!(
            "submitted={} completed={} shed={} quota_denied={} queue_depth={}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.quota_denied.load(Ordering::Relaxed),
            self.queue_depth.load(Ordering::Relaxed),
        )
    }
}

/// The serving metrics registry; one instance is shared by the
/// coordinator and every middleware layer in front of it.
#[derive(Debug)]
pub struct Metrics {
    /// Requests submitted to the coordinator intake.
    pub submitted: AtomicU64,
    /// Requests answered by a decode worker.
    pub completed: AtomicU64,
    /// Bounced at the coordinator intake (queue full).
    pub rejected: AtomicU64,
    /// Completed requests whose generation satisfied the constraint.
    pub satisfied: AtomicU64,
    /// Constraint-table cache hits (dispatcher, per concept group).
    pub table_cache_hits: AtomicU64,
    /// Constraint-table cache misses (a table had to be built).
    pub table_cache_misses: AtomicU64,
    /// Cumulative **microseconds** spent in completed constraint-table
    /// builds (abandoned deadline-expired builds are not counted) —
    /// micros so sub-millisecond sparse builds still register; the
    /// summary renders it as `table_build_ms`. Divide by
    /// `table_cache_misses` for the mean build cost the sparse table
    /// engine is driving down.
    pub table_build_us: AtomicU64,
    /// Gauge: bytes currently resident in the constraint-table cache
    /// (the byte-budgeted LRU's accounting, updated on every insert).
    pub table_bytes: AtomicU64,
    /// Rejected by the `LoadShed` middleware before reaching the queue.
    pub shed: AtomicU64,
    /// Requests whose deadline fired (`Timeout` middleware).
    pub timed_out: AtomicU64,
    /// Requests the `Hedge` middleware re-dispatched.
    pub hedged: AtomicU64,
    /// Hedged requests where the second dispatch answered first.
    pub hedge_wins: AtomicU64,
    /// Requests denied by the `Quota` middleware.
    pub quota_denied: AtomicU64,
    /// Requests shed by `FairQueue` (per-client queue overflow).
    pub fair_shed: AtomicU64,
    /// Requests shed by `AdaptiveShed` (derived in-flight limit hit).
    pub adaptive_shed: AtomicU64,
    /// Gauge: the in-flight limit `AdaptiveShed` most recently derived
    /// from observed service time (Little's law).
    pub adaptive_limit: AtomicU64,
    /// Approximate intake-queue depth (requests accepted but not yet
    /// picked up by the dispatcher).
    pub queue_depth: AtomicU64,
    /// Requests admitted and not yet answered, wherever they sit
    /// (intake queue, batch channel, or a decode worker). This is the
    /// admission signal behind `Server::poll_ready`: the intake queue
    /// alone drains into the dispatcher too fast to reflect saturation.
    pub in_flight: AtomicU64,
    /// Per-client breakdown, keyed by `Keyed::client_id`. Entries are
    /// created on first touch and kept for the registry's lifetime
    /// (client cardinality is assumed bounded — ids are tenants or API
    /// keys, not request ids). Read-mostly after warmup, so lookups
    /// take a shared lock: rejection hot paths in the shed layers do
    /// not serialize on each other.
    clients: RwLock<HashMap<String, Arc<ClientStats>>>,
    /// end-to-end latencies (seconds), reservoir-sampled
    latencies: Mutex<Reservoir>,
    /// time spent queued before a worker picked the request up
    queue_waits: Mutex<Reservoir>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::with_reservoir(RESERVOIR_CAP)
    }
}

impl Metrics {
    /// A fresh registry with the default reservoir capacity.
    pub fn new() -> Self {
        Self::default()
    }

    /// A registry whose latency reservoirs retain at most `cap` samples.
    pub fn with_reservoir(cap: usize) -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            satisfied: AtomicU64::new(0),
            table_cache_hits: AtomicU64::new(0),
            table_cache_misses: AtomicU64::new(0),
            table_build_us: AtomicU64::new(0),
            table_bytes: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            timed_out: AtomicU64::new(0),
            hedged: AtomicU64::new(0),
            hedge_wins: AtomicU64::new(0),
            quota_denied: AtomicU64::new(0),
            fair_shed: AtomicU64::new(0),
            adaptive_shed: AtomicU64::new(0),
            adaptive_limit: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            clients: RwLock::new(HashMap::new()),
            latencies: Mutex::new(Reservoir::new(cap)),
            queue_waits: Mutex::new(Reservoir::new(cap)),
        }
    }

    /// The counter block for `client_id`, created on first touch.
    /// Existing clients resolve through a shared read lock with no
    /// allocation; layers additionally cache the returned handle where
    /// they can (the lock is per-lookup, not per-increment).
    pub fn client(&self, client_id: &str) -> Arc<ClientStats> {
        if let Some(stats) = self.clients.read().unwrap().get(client_id) {
            return Arc::clone(stats);
        }
        let mut clients = self.clients.write().unwrap();
        Arc::clone(
            clients
                .entry(client_id.to_string())
                .or_insert_with(|| Arc::new(ClientStats::default())),
        )
    }

    /// Every client seen so far, sorted by id.
    pub fn clients_snapshot(&self) -> Vec<(String, Arc<ClientStats>)> {
        let clients = self.clients.read().unwrap();
        let mut rows: Vec<_> = clients
            .iter()
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }

    /// Multi-line per-client rendering (one `id: counters…` row per
    /// client); empty string when no client was ever attributed.
    pub fn client_summary(&self) -> String {
        self.clients_snapshot()
            .iter()
            .map(|(id, stats)| format!("client {id}: {}", stats.summary()))
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Record one completed request's end-to-end latency and the part
    /// of it spent queued (both in seconds).
    pub fn record_latency(&self, total: f64, queued: f64) {
        self.latencies.lock().unwrap().push(total);
        self.queue_waits.lock().unwrap().push(queued);
    }

    /// Quantiles over the (reservoir-sampled) end-to-end latencies;
    /// `None` before the first completion.
    pub fn latency_stats(&self) -> Option<Stats> {
        let l = self.latencies.lock().unwrap();
        if l.is_empty() {
            None
        } else {
            Some(Stats::of(l.samples()))
        }
    }

    /// Quantiles over the (reservoir-sampled) queue waits; `None`
    /// before the first completion.
    pub fn queue_stats(&self) -> Option<Stats> {
        let q = self.queue_waits.lock().unwrap();
        if q.is_empty() {
            None
        } else {
            Some(Stats::of(q.samples()))
        }
    }

    /// One-line global rendering of every counter plus the latency
    /// quantiles; per-client rows live in [`Metrics::client_summary`].
    pub fn summary(&self) -> String {
        let lat = self
            .latency_stats()
            .map(|s| {
                format!(
                    "latency p50={} p95={} p99={} max={}",
                    crate::util::timer::fmt_secs(s.p50),
                    crate::util::timer::fmt_secs(s.p95),
                    crate::util::timer::fmt_secs(s.p99),
                    crate::util::timer::fmt_secs(s.max)
                )
            })
            .unwrap_or_else(|| "latency n/a".into());
        format!(
            "submitted={} completed={} rejected={} shed={} quota_denied={} fair_shed={} adaptive_shed={} adaptive_limit={} timed_out={} hedged={} hedge_wins={} satisfied={} cache h/m={}/{} table_build_ms={:.1} table_bytes={} {}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.quota_denied.load(Ordering::Relaxed),
            self.fair_shed.load(Ordering::Relaxed),
            self.adaptive_shed.load(Ordering::Relaxed),
            self.adaptive_limit.load(Ordering::Relaxed),
            self.timed_out.load(Ordering::Relaxed),
            self.hedged.load(Ordering::Relaxed),
            self.hedge_wins.load(Ordering::Relaxed),
            self.satisfied.load(Ordering::Relaxed),
            self.table_cache_hits.load(Ordering::Relaxed),
            self.table_cache_misses.load(Ordering::Relaxed),
            self.table_build_us.load(Ordering::Relaxed) as f64 / 1e3,
            self.table_bytes.load(Ordering::Relaxed),
            lat
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        m.record_latency(0.010, 0.001);
        m.record_latency(0.020, 0.002);
        let s = m.latency_stats().unwrap();
        assert_eq!(s.n, 2);
        assert!((s.mean - 0.015).abs() < 1e-9);
        assert!(m.summary().contains("submitted=3"));
    }

    #[test]
    fn client_stats_attribute_per_client() {
        let m = Metrics::new();
        m.client("alice").submitted.fetch_add(2, Ordering::Relaxed);
        m.client("alice").completed.fetch_add(2, Ordering::Relaxed);
        m.client("bob").quota_denied.fetch_add(1, Ordering::Relaxed);
        // Handles are shared, not copies.
        assert_eq!(m.client("alice").submitted.load(Ordering::Relaxed), 2);
        let rows = m.clients_snapshot();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "alice");
        assert_eq!(rows[1].0, "bob");
        let summary = m.client_summary();
        assert!(summary.contains("client alice: submitted=2"), "{summary}");
        assert!(summary.contains("client bob:"), "{summary}");
        assert!(summary.contains("quota_denied=1"), "{summary}");
    }

    #[test]
    fn empty_latencies_are_none() {
        let m = Metrics::new();
        assert!(m.latency_stats().is_none());
        assert!(m.summary().contains("n/a"));
    }

    #[test]
    fn reservoir_stays_bounded() {
        let mut r = Reservoir::new(64);
        for i in 0..100_000 {
            r.push(i as f64);
        }
        assert_eq!(r.samples().len(), 64);
        assert_eq!(r.seen(), 100_000);
    }

    #[test]
    fn reservoir_quantiles_track_the_stream() {
        // Uniform stream 0..50_000: a 1024-sample reservoir's median must
        // land near 25_000 (sampling is deterministic via the seeded RNG).
        let mut r = Reservoir::new(1024);
        for i in 0..50_000 {
            r.push(i as f64);
        }
        let s = Stats::of(r.samples());
        assert_eq!(s.n, 1024);
        assert!(
            (s.p50 - 25_000.0).abs() < 2_500.0,
            "reservoir median drifted: {}",
            s.p50
        );
        assert!(s.min >= 0.0 && s.max < 50_000.0);
    }

    #[test]
    fn metrics_latency_memory_is_bounded() {
        let m = Metrics::with_reservoir(32);
        for i in 0..10_000 {
            m.record_latency(i as f64 * 1e-4, 1e-5);
        }
        let s = m.latency_stats().unwrap();
        assert_eq!(s.n, 32, "reservoir must cap retained samples");
    }
}
