//! Leveled stderr logging (the `log` facade isn't wired to anything in the
//! offline set, so we own a minimal logger). Level is controlled by
//! `NORMQ_LOG` (error|warn|info|debug|trace), default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, most severe first.
#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[allow(missing_docs)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

/// Read `NORMQ_LOG` and set the level (also anchors the log clock).
pub fn init_from_env() {
    let lvl = match std::env::var("NORMQ_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_level(lvl);
    let _ = START.set(Instant::now());
}

/// Set the global log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Whether messages at level `l` are currently emitted.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one message (used via the `log_*` macros).
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:9.3}s {}] {}", t, tag, args);
}

/// Log at `Info` level with `format!` syntax.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*))
    };
}

/// Log at `Warn` level with `format!` syntax.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at `Debug` level with `format!` syntax.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*))
    };
}

/// Log at `Error` level with `format!` syntax.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
