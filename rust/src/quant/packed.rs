//! Bit-packed storage for Norm-Q quantized matrices, plus the
//! compression-rate accounting the paper reports (§IV-B: 99.9825% at
//! 8 bits, 99.9992% at 3 bits).
//!
//! A Norm-Q'd matrix is fully determined by its integer levels: the
//! dequantized value is `level / Σ_row levels` (the ε-mass on zero levels
//! is below f32 resolution for any realistic row). We therefore store
//! only b-bit levels:
//!
//! - `PackedMat` — dense bit-packing, `rows*cols*b` bits + one f32
//!   row-scale cache per row;
//! - `SparseQMat` — CSR-style packing of *non-zero* levels only, which is
//!   where the ≥99% compression comes from (after Norm-Q at b ≤ 8 the
//!   overwhelming majority of levels are zero).
//!
//! Both support the decode-path hot op (`vecmat`: alpha' = alpha @ M with
//! on-the-fly dequantization) so the serving layer never materializes
//! dense FP32 weights.

use crate::util::kernel::{self, KernelScratch};
use crate::util::mat::Mat;

/// Dense bit-packed quantized matrix (levels in [0, 2^bits - 1]).
#[derive(Clone, Debug)]
pub struct PackedMat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Bits per stored level.
    pub bits: u32,
    words: Vec<u64>,
    /// Cached 1/Σ levels per row (f32, not counted as model storage: it
    /// is recomputable from the levels).
    row_scale: Vec<f32>,
}

impl PackedMat {
    /// Quantize `m` (a row-stochastic matrix) at `bits` with Norm-Q
    /// semantics: fixed-point levels, per-row normalization by level sum.
    pub fn from_mat(m: &Mat, bits: u32) -> PackedMat {
        assert!(bits >= 1 && bits <= 16);
        let per_word = 64 / bits as usize;
        let words_per_row = (m.cols + per_word - 1) / per_word;
        let mut words = vec![0u64; m.rows * words_per_row];
        let mut row_scale = vec![0f32; m.rows];
        for r in 0..m.rows {
            let mut sum = 0u64;
            for c in 0..m.cols {
                let lvl = crate::quant::fixed::level(m.at(r, c), bits) as u64;
                sum += lvl;
                let idx = r * words_per_row + c / per_word;
                let shift = (c % per_word) as u32 * bits;
                words[idx] |= lvl << shift;
            }
            row_scale[r] = if sum > 0 { 1.0 / sum as f32 } else { 1.0 / m.cols as f32 };
        }
        PackedMat { rows: m.rows, cols: m.cols, bits, words, row_scale }
    }

    #[inline]
    fn per_word(&self) -> usize {
        64 / self.bits as usize
    }

    #[inline]
    fn words_per_row(&self) -> usize {
        (self.cols + self.per_word() - 1) / self.per_word()
    }

    /// Integer level at (r, c).
    #[inline]
    pub fn level(&self, r: usize, c: usize) -> u32 {
        let per_word = self.per_word();
        let idx = r * self.words_per_row() + c / per_word;
        let shift = (c % per_word) as u32 * self.bits;
        let mask = if self.bits == 64 { u64::MAX } else { (1u64 << self.bits) - 1 };
        ((self.words[idx] >> shift) & mask) as u32
    }

    /// Dequantized (Norm-Q) value at (r, c).
    #[inline]
    pub fn value(&self, r: usize, c: usize) -> f32 {
        self.level(r, c) as f32 * self.row_scale[r]
    }

    /// Materialize the dense dequantized matrix (for tests / M-step).
    pub fn to_mat(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let s = self.row_scale[r];
            if self.row_scale_sum_zero(r) {
                // all-zero row dequantizes to uniform (Norm-Q ε behaviour)
                for c in 0..self.cols {
                    m.set(r, c, 1.0 / self.cols as f32);
                }
            } else {
                for c in 0..self.cols {
                    m.set(r, c, self.level(r, c) as f32 * s);
                }
            }
        }
        m
    }

    fn row_scale_sum_zero(&self, r: usize) -> bool {
        // row_scale was set to 1/cols exactly when the level sum was 0.
        (self.row_scale[r] - 1.0 / self.cols as f32).abs() < f32::EPSILON
            && (0..self.cols).all(|c| self.level(r, c) == 0)
    }

    /// out = v (1 x rows) @ dequant(self): the decode hot path, unpacking
    /// levels word-by-word and skipping zero inputs/levels.
    ///
    /// Perf (EXPERIMENTS.md §Perf): the unpack loop walks a per-word
    /// slice of the accumulator (`iter_mut`), which elides the per-element
    /// bounds check the original index-based loop paid, and zero words
    /// (the common case after Norm-Q auto-pruning) skip in one test.
    ///
    /// Fully-pruned rows (every level zero) dequantize to *uniform*,
    /// matching [`PackedMat::to_mat`]: their mass folds into one rank-1
    /// pass at the end instead of silently dropping.
    pub fn vecmat(&self, v: &[f32], out: &mut [f32]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        let bits = self.bits;
        let per_word = self.per_word();
        let wpr = self.words_per_row();
        let mask = (1u64 << bits) - 1;
        let mut acc = vec![0f64; self.cols];
        // Σ over dead rows of v[r]/cols (row_scale is 1/cols exactly
        // when the row's level sum was 0).
        let mut uniform = 0f64;
        for (r, &vr) in v.iter().enumerate() {
            if vr == 0.0 {
                continue;
            }
            let scaled = (vr * self.row_scale[r]) as f64;
            let row_words = &self.words[r * wpr..(r + 1) * wpr];
            if row_words.iter().all(|&w| w == 0) {
                uniform += scaled;
                continue;
            }
            for (wi, &w0) in row_words.iter().enumerate() {
                if w0 == 0 {
                    continue;
                }
                let base = wi * per_word;
                let n = per_word.min(self.cols - base);
                let mut w = w0;
                for slot in acc[base..base + n].iter_mut() {
                    // Unconditional FMA: a zero level adds 0.0, which is
                    // cheaper than the branch misprediction the `if lvl`
                    // guard cost inside non-zero words (§Perf iteration 2).
                    *slot += scaled * (w & mask) as f64;
                    w >>= bits;
                }
            }
        }
        if uniform != 0.0 {
            for a in acc.iter_mut() {
                *a += uniform;
            }
        }
        for (o, a) in out.iter_mut().zip(acc.iter()) {
            *o = *a as f32;
        }
    }

    /// Panel form of [`PackedMat::vecmat`]: `b` input vectors at once,
    /// laid out back to back (`panel[bi·rows .. (bi+1)·rows]` is beam
    /// `bi`'s vector; `out` uses the same layout over `cols`).
    ///
    /// Each non-zero word is unpacked **once** and its levels applied
    /// to all live beams through a column-major `f64` accumulator
    /// panel (the `b` accumulators of one output column are
    /// contiguous), so the bit-unpacking cost and the word stream
    /// amortize over the panel instead of being re-paid per beam.
    ///
    /// Bit-identical to `b` independent `vecmat` calls: per beam the
    /// same rows are skipped (the guard is on the raw `vr == 0.0`,
    /// before scaling), the same `scaled · level` additions land in
    /// the same ascending (row, slot) order — including the
    /// unconditional add of zero levels inside non-zero words — and
    /// the dead-row uniform mass folds in through the same single
    /// rank-1 pass per beam at the end.
    ///
    /// Allocates a fresh serial [`KernelScratch`] per call; hot paths
    /// should hold one and use [`PackedMat::vecmat_panel_with`].
    pub fn vecmat_panel(&self, panel: &[f32], b: usize, out: &mut [f32]) {
        self.vecmat_panel_with(panel, b, out, &mut KernelScratch::new());
    }

    /// [`PackedMat::vecmat_panel`] through the cache-blocked
    /// micro-kernel layer (`util::kernel`), with caller-owned scratch:
    /// output columns are tiled into word-aligned L2-sized blocks
    /// (block boundaries land on packed-word boundaries, so a word's
    /// slots never straddle two blocks), each non-zero word is
    /// unpacked once per pass and its levels applied to all live beams
    /// through the fixed-width rank-1 micro-kernels, and column blocks
    /// fan out across the scratch's thread budget behind a work-size
    /// gate. Every (beam, column) accumulator is owned by exactly one
    /// block and one thread, so the per-accumulator addition order —
    /// and therefore the bit-identity to the scalar path — is
    /// untouched.
    pub fn vecmat_panel_with(
        &self,
        panel: &[f32],
        b: usize,
        out: &mut [f32],
        scratch: &mut KernelScratch,
    ) {
        assert_eq!(panel.len(), b * self.rows);
        assert_eq!(out.len(), b * self.cols);
        if b == 1 {
            return self.vecmat(panel, out);
        }
        let bits = self.bits;
        let per_word = self.per_word();
        let wpr = self.words_per_row();
        let lvl_mask = (1u64 << bits) - 1;
        scratch.prepare(self.rows, self.cols, b);
        let plan = scratch.plan(self.cols, b, per_word, self.rows * self.cols * b);
        let KernelScratch { acc, scale, mask, kind, uniform, .. } = &mut *scratch;
        let rs = Some(&self.row_scale[..]);
        kernel::plan_rows(scale, mask, kind, uniform, panel, b, self.rows, rs, |r| {
            self.words[r * wpr..(r + 1) * wpr].iter().all(|&w| w == 0)
        });
        let (scale, mask, kind) = (&scale[..], &mask[..], &kind[..]);
        kernel::par_blocks(acc, b, self.cols, plan, |c0, c1, accb| {
            // c0 is word-aligned (plan align = per_word); the last
            // block's final word may be a partial tail, bounded by n.
            let w0i = c0 / per_word;
            let w1i = (c1 + per_word - 1) / per_word;
            for r in 0..self.rows {
                let k = kind[r];
                if k == kernel::ROW_SKIP || k == kernel::ROW_DEAD {
                    continue;
                }
                let srow = &scale[r * b..(r + 1) * b];
                let mrow = &mask[r * b..(r + 1) * b];
                let row_words = &self.words[r * wpr + w0i..r * wpr + w1i];
                for (wj, &w0) in row_words.iter().enumerate() {
                    if w0 == 0 {
                        continue;
                    }
                    let base = (w0i + wj) * per_word;
                    let n = per_word.min(self.cols - base);
                    let mut w = w0;
                    for slot in 0..n {
                        let lvl = (w & lvl_mask) as f64;
                        w >>= bits;
                        let j = base + slot - c0;
                        let col = &mut accb[j * b..(j + 1) * b];
                        if k == kernel::ROW_ALL {
                            kernel::rank1_all(col, srow, lvl);
                        } else {
                            kernel::rank1_masked(col, srow, mrow, lvl);
                        }
                    }
                }
            }
        });
        kernel::par_writeback(out, acc, uniform, b, self.cols, plan.threads);
    }

    /// Model storage in bits: the packed levels only (row scales are
    /// derived). This matches the paper's "b-bit fixed point" accounting.
    pub fn storage_bits(&self) -> usize {
        self.rows * self.cols * self.bits as usize
    }
}

/// CSR-style sparse quantized matrix: only non-zero levels stored.
#[derive(Clone, Debug)]
pub struct SparseQMat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Bits per stored level.
    pub bits: u32,
    /// CSR row offsets into `col_idx`/`levels`, length `rows + 1`.
    pub row_ptr: Vec<u32>,
    /// Column index per stored non-zero.
    pub col_idx: Vec<u32>,
    /// Quantized level per stored non-zero.
    pub levels: Vec<u16>,
    row_scale: Vec<f32>,
}

impl SparseQMat {
    /// Quantize `m` at `bits`, storing only non-zero levels.
    pub fn from_mat(m: &Mat, bits: u32) -> SparseQMat {
        assert!(bits >= 1 && bits <= 16);
        let mut row_ptr = Vec::with_capacity(m.rows + 1);
        let mut col_idx = Vec::new();
        let mut levels = Vec::new();
        let mut row_scale = vec![0f32; m.rows];
        row_ptr.push(0u32);
        for r in 0..m.rows {
            let mut sum = 0u64;
            for c in 0..m.cols {
                let lvl = crate::quant::fixed::level(m.at(r, c), bits);
                if lvl != 0 {
                    col_idx.push(c as u32);
                    levels.push(lvl as u16);
                    sum += lvl as u64;
                }
            }
            row_ptr.push(col_idx.len() as u32);
            row_scale[r] = if sum > 0 { 1.0 / sum as f32 } else { 1.0 / m.cols as f32 };
        }
        SparseQMat { rows: m.rows, cols: m.cols, bits, row_ptr, col_idx, levels, row_scale }
    }

    /// Assemble a CSR matrix directly from its parts, computing the
    /// Norm-Q row scales (`1/Σ levels`, `1/cols` for stored-out rows)
    /// internally so the dequantization invariant cannot be violated.
    ///
    /// This is the synthesis path for serving-scale models: benches and
    /// tests build H=16k/64k backends level-by-level, where the dense
    /// H×H intermediate that [`SparseQMat::from_mat`] quantizes would
    /// be tens of gigabytes (64k² FP32 ≈ 17 GB).
    ///
    /// Panics when the parts are inconsistent: `row_ptr` must be
    /// monotone with `rows + 1` entries ending at `levels.len()`,
    /// `col_idx` entries must be `< cols` and strictly ascending
    /// within each row (the layout [`SparseQMat::level_at`]'s binary
    /// search relies on), and every stored level must be non-zero and
    /// fit in `bits`.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        bits: u32,
        row_ptr: Vec<u32>,
        col_idx: Vec<u32>,
        levels: Vec<u16>,
    ) -> SparseQMat {
        assert!(bits >= 1 && bits <= 16);
        assert_eq!(row_ptr.len(), rows + 1, "row_ptr must have rows + 1 entries");
        assert_eq!(row_ptr[0], 0, "row_ptr must start at 0");
        assert_eq!(
            *row_ptr.last().unwrap() as usize,
            levels.len(),
            "row_ptr must end at the stored count"
        );
        assert_eq!(col_idx.len(), levels.len());
        let max_level = ((1u32 << bits) - 1) as u16;
        let mut row_scale = vec![0f32; rows];
        for r in 0..rows {
            let lo = row_ptr[r] as usize;
            let hi = row_ptr[r + 1] as usize;
            assert!(lo <= hi, "row_ptr must be monotone (row {r})");
            let mut sum = 0u64;
            for i in lo..hi {
                assert!((col_idx[i] as usize) < cols, "col_idx out of range (row {r})");
                assert!(i == lo || col_idx[i - 1] < col_idx[i], "col_idx not ascending (row {r})");
                assert!(
                    levels[i] != 0 && levels[i] <= max_level,
                    "level out of range for bits={bits} (row {r})"
                );
                sum += levels[i] as u64;
            }
            row_scale[r] = if sum > 0 { 1.0 / sum as f32 } else { 1.0 / cols as f32 };
        }
        SparseQMat { rows, cols, bits, row_ptr, col_idx, levels, row_scale }
    }

    /// Stored non-zero count.
    pub fn nnz(&self) -> usize {
        self.levels.len()
    }

    /// out = v @ dequant(self) over non-zeros only — the decode-path
    /// acceptance product (`u @ emit`) and forward step (`v @ trans`).
    ///
    /// Rows with no stored level dequantize to *uniform* (matching
    /// [`SparseQMat::to_mat`]/[`SparseQMat::matvec`]): their
    /// contribution is the same `v[r]/cols` in every column, folded
    /// into one rank-1 pass at the end, so the sparse backend and the
    /// dense dequantization of the same levels agree even when
    /// quantization auto-pruned a whole row.
    pub fn vecmat(&self, v: &[f32], out: &mut [f32]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        let mut acc = vec![0f64; self.cols];
        // Σ over dead rows of v[r]/cols (row_scale is 1/cols exactly
        // when the row stored nothing).
        let mut uniform = 0f64;
        for (r, &vr) in v.iter().enumerate() {
            if vr == 0.0 {
                continue;
            }
            let scaled = (vr * self.row_scale[r]) as f64;
            let lo = self.row_ptr[r] as usize;
            let hi = self.row_ptr[r + 1] as usize;
            if lo == hi {
                uniform += scaled;
                continue;
            }
            for i in lo..hi {
                acc[self.col_idx[i] as usize] += scaled * self.levels[i] as f64;
            }
        }
        if uniform != 0.0 {
            for a in acc.iter_mut() {
                *a += uniform;
            }
        }
        for (o, a) in out.iter_mut().zip(acc.iter()) {
            *o = *a as f32;
        }
    }

    /// Panel form of [`SparseQMat::vecmat`]: `b` input vectors at
    /// once, laid out back to back (`panel[bi·rows .. (bi+1)·rows]` is
    /// beam `bi`'s vector; `out` uses the same layout over `cols`).
    /// This is the batched decode engine's CSR × dense-panel kernel:
    /// each stored level (and its column index) is read and
    /// dequantized **once** and applied to all live beams via a
    /// rank-1 update into a column-major `f64` accumulator panel — the
    /// `b` accumulators of one output column are contiguous, so the
    /// inner loop is unit-stride no matter how scattered the CSR
    /// columns are. `b` independent `vecmat` calls instead re-stream
    /// the CSR arrays (`u16` level + `u32` column per non-zero) from
    /// DRAM once per beam, which is what makes the per-beam loop
    /// memory-bound at serving-scale H.
    ///
    /// Bit-identical to `b` independent `vecmat` calls, by
    /// construction: per beam, rows are visited in the same ascending
    /// order, skipped on the same raw `vr == 0.0` guard (before
    /// scaling — `vr · row_scale` can underflow to zero for a `vr` the
    /// scalar path would still process), accumulate the identical
    /// `scaled · level` f64 sequence per output column, fold dead-row
    /// uniform mass through the same end pass, and round f64 → f32
    /// once at the end. No accumulator is shared between beams, so
    /// interleaving beams cannot reassociate any beam's sum.
    /// `tests/decode_equivalence.rs` asserts the bit-level match across
    /// the full bits × sparsity × H × B matrix.
    ///
    /// Allocates a fresh serial [`KernelScratch`] per call; hot paths
    /// should hold one and use [`SparseQMat::vecmat_panel_with`].
    pub fn vecmat_panel(&self, panel: &[f32], b: usize, out: &mut [f32]) {
        self.vecmat_panel_with(panel, b, out, &mut KernelScratch::new());
    }

    /// [`SparseQMat::vecmat_panel`] through the cache-blocked
    /// micro-kernel layer (`util::kernel`), with caller-owned scratch:
    /// output columns are tiled into L2-sized blocks so the rank-1
    /// scatter of a CSR row's levels stays inside a cache-resident
    /// accumulator tile (at serving scale the full `b × cols` f64
    /// panel is tens of megabytes — the per-entry scatter was a DRAM
    /// round-trip per level). Each pass binary-searches the row's
    /// sorted column indices for the block's start
    /// (`partition_point`), walks entries until the block's end, and
    /// applies the fixed-width rank-1 micro-kernels. Column blocks fan
    /// out across the scratch's thread budget behind a work-size gate;
    /// every (beam, column) accumulator is owned by exactly one block
    /// and one thread, so the per-accumulator addition order — and the
    /// bit-identity to the scalar path — is untouched.
    pub fn vecmat_panel_with(
        &self,
        panel: &[f32],
        b: usize,
        out: &mut [f32],
        scratch: &mut KernelScratch,
    ) {
        assert_eq!(panel.len(), b * self.rows);
        assert_eq!(out.len(), b * self.cols);
        if b == 1 {
            return self.vecmat(panel, out);
        }
        scratch.prepare(self.rows, self.cols, b);
        let plan = scratch.plan(self.cols, b, 1, self.nnz() * b);
        let KernelScratch { acc, scale, mask, kind, uniform, .. } = &mut *scratch;
        let rs = Some(&self.row_scale[..]);
        kernel::plan_rows(scale, mask, kind, uniform, panel, b, self.rows, rs, |r| {
            self.row_ptr[r] == self.row_ptr[r + 1]
        });
        let (scale, mask, kind) = (&scale[..], &mask[..], &kind[..]);
        kernel::par_blocks(acc, b, self.cols, plan, |c0, c1, accb| {
            for r in 0..self.rows {
                let k = kind[r];
                if k == kernel::ROW_SKIP || k == kernel::ROW_DEAD {
                    continue;
                }
                let lo = self.row_ptr[r] as usize;
                let hi = self.row_ptr[r + 1] as usize;
                let start = if c0 == 0 {
                    lo
                } else {
                    lo + self.col_idx[lo..hi].partition_point(|&c| (c as usize) < c0)
                };
                let srow = &scale[r * b..(r + 1) * b];
                if k == kernel::ROW_ALL {
                    for i in start..hi {
                        let c = self.col_idx[i] as usize;
                        if c >= c1 {
                            break;
                        }
                        let j = c - c0;
                        kernel::rank1_all(
                            &mut accb[j * b..(j + 1) * b],
                            srow,
                            self.levels[i] as f64,
                        );
                    }
                } else {
                    let mrow = &mask[r * b..(r + 1) * b];
                    for i in start..hi {
                        let c = self.col_idx[i] as usize;
                        if c >= c1 {
                            break;
                        }
                        let j = c - c0;
                        kernel::rank1_masked(
                            &mut accb[j * b..(j + 1) * b],
                            srow,
                            mrow,
                            self.levels[i] as f64,
                        );
                    }
                }
            }
        });
        kernel::par_writeback(out, acc, uniform, b, self.cols, plan.threads);
    }

    /// out = dequant(self) @ v (one value per row, f64 accumulators) —
    /// the backward-transition step of the constraint-table engine,
    /// walking stored non-zeros only: O(nnz) instead of O(rows·cols).
    ///
    /// Rows with no stored level dequantize to *uniform* (matching
    /// [`SparseQMat::to_mat`]'s Norm-Q ε behaviour), so an all-zero
    /// quantized row contributes the mean of `v` rather than silently
    /// dropping probability mass.
    pub fn matvec(&self, v: &[f32], out: &mut [f32]) {
        assert_eq!(v.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        // Mean of v, computed once and only if some row needs it.
        let mut uniform: Option<f64> = None;
        for (r, o) in out.iter_mut().enumerate() {
            let lo = self.row_ptr[r] as usize;
            let hi = self.row_ptr[r + 1] as usize;
            if lo == hi {
                let u = *uniform.get_or_insert_with(|| {
                    v.iter().map(|&x| x as f64).sum::<f64>() / self.cols as f64
                });
                *o = u as f32;
                continue;
            }
            let mut acc = 0f64;
            for i in lo..hi {
                acc += self.levels[i] as f64 * v[self.col_idx[i] as usize] as f64;
            }
            *o = (acc * self.row_scale[r] as f64) as f32;
        }
    }

    /// Stored level at `(r, c)` (0 when the entry is not stored), via
    /// binary search inside the row's sorted column indices.
    pub fn level_at(&self, r: usize, c: usize) -> u32 {
        let lo = self.row_ptr[r] as usize;
        let hi = self.row_ptr[r + 1] as usize;
        match self.col_idx[lo..hi].binary_search(&(c as u32)) {
            Ok(i) => self.levels[lo + i] as u32,
            Err(_) => 0,
        }
    }

    /// Dequantized value at `(r, c)`; all-zero rows read as uniform
    /// (consistent with [`SparseQMat::to_mat`] and
    /// [`SparseQMat::matvec`]).
    pub fn value(&self, r: usize, c: usize) -> f32 {
        let lo = self.row_ptr[r] as usize;
        let hi = self.row_ptr[r + 1] as usize;
        if lo == hi {
            return 1.0 / self.cols as f32;
        }
        self.level_at(r, c) as f32 * self.row_scale[r]
    }

    /// Bytes the CSR arrays actually occupy in memory (levels, column
    /// indices, row pointers, row scales) — the resident footprint a
    /// byte-budgeted cache accounts, as opposed to the information-
    /// theoretic [`SparseQMat::storage_bits`].
    pub fn resident_bytes(&self) -> usize {
        self.levels.len() * 2
            + self.col_idx.len() * 4
            + self.row_ptr.len() * 4
            + self.row_scale.len() * 4
    }

    /// Storage bits: levels at b bits + column indices at ceil(log2 cols)
    /// + row pointers at 32 bits.
    pub fn storage_bits(&self) -> usize {
        let idx_bits = (usize::BITS - (self.cols.max(2) - 1).leading_zeros()) as usize;
        self.nnz() * (self.bits as usize + idx_bits) + (self.rows + 1) * 32
    }

    /// Dequantize back to a dense row-stochastic matrix.
    pub fn to_mat(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let lo = self.row_ptr[r] as usize;
            let hi = self.row_ptr[r + 1] as usize;
            if lo == hi {
                for c in 0..self.cols {
                    m.set(r, c, 1.0 / self.cols as f32);
                }
                continue;
            }
            for i in lo..hi {
                m.set(r, self.col_idx[i] as usize, self.levels[i] as f32 * self.row_scale[r]);
            }
        }
        m
    }
}

/// Compression report for one matrix at one bit width.
#[derive(Clone, Copy, Debug)]
pub struct CompressionReport {
    /// Uncompressed size (32 bits per entry).
    pub fp32_bits: usize,
    /// Dense bit-packed size at `bits` per entry.
    pub dense_packed_bits: usize,
    /// CSR sparse size (levels + indices + row pointers).
    pub sparse_bits: usize,
    /// Non-zero count after quantization.
    pub nnz: usize,
    /// Total entries.
    pub total: usize,
}

impl CompressionReport {
    /// Measure `m` quantized at `bits` under both storage layouts.
    pub fn of(m: &Mat, bits: u32) -> CompressionReport {
        let packed = PackedMat::from_mat(m, bits);
        let sparse = SparseQMat::from_mat(m, bits);
        CompressionReport {
            fp32_bits: m.data.len() * 32,
            dense_packed_bits: packed.storage_bits(),
            sparse_bits: sparse.storage_bits(),
            nnz: sparse.nnz(),
            total: m.data.len(),
        }
    }

    /// 1 - compressed/original, using the better of dense-packed and
    /// sparse representations (what the paper's ">99%" refers to).
    pub fn compression_rate(&self) -> f64 {
        let best = self.dense_packed_bits.min(self.sparse_bits);
        1.0 - best as f64 / self.fp32_bits as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::normq;
    use crate::util::proptest::{gen, Prop};
    use crate::util::rng::Rng;

    #[test]
    fn packed_roundtrip_matches_normq() {
        Prop::new(24, 71).run("packed-roundtrip", |rng, _| {
            let m = gen::stochastic_mat(rng, 8, 33); // odd cols: partial word
            let bits = [2u32, 3, 4, 7, 8][rng.below_usize(5)];
            let packed = PackedMat::from_mat(&m, bits);
            let dense = packed.to_mat();
            // Norm-Q reference: qdq then row normalize. Compare where the
            // row has any surviving mass (ε-mass rows differ by design).
            let mut reference = m.clone();
            normq::normq_mat(&mut reference, bits, 0.0);
            for r in 0..m.rows {
                let any = (0..m.cols).any(|c| packed.level(r, c) > 0);
                if !any {
                    continue;
                }
                for c in 0..m.cols {
                    let d = (dense.at(r, c) - reference.at(r, c)).abs();
                    assert!(d < 1e-5, "bits={bits} r={r} c={c} d={d}");
                }
            }
        });
    }

    #[test]
    fn sparse_matches_packed() {
        Prop::new(16, 72).run("sparse-matches-packed", |rng, _| {
            let m = gen::stochastic_mat(rng, 6, 40);
            let bits = [3u32, 8][rng.below_usize(2)];
            let packed = PackedMat::from_mat(&m, bits).to_mat();
            let sparse = SparseQMat::from_mat(&m, bits).to_mat();
            assert!(packed.max_abs_diff(&sparse) < 1e-6);
        });
    }

    #[test]
    fn vecmat_matches_dense_reference() {
        Prop::new(16, 73).run("packed-vecmat", |rng, _| {
            let m = crate::util::mat::Mat::random_stochastic(7, 19, 0.3, rng);
            let bits = 8;
            let packed = PackedMat::from_mat(&m, bits);
            let sparse = SparseQMat::from_mat(&m, bits);
            let dense = packed.to_mat();
            let v: Vec<f32> = rng.dirichlet_symmetric(7, 1.0);
            let mut want = vec![0f32; 19];
            dense.vecmat(&v, &mut want);
            let mut got_p = vec![0f32; 19];
            packed.vecmat(&v, &mut got_p);
            let mut got_s = vec![0f32; 19];
            sparse.vecmat(&v, &mut got_s);
            for c in 0..19 {
                // Both vecmats dequantize dead rows to uniform, matching
                // to_mat — only float rounding order differs.
                assert!((want[c] - got_p[c]).abs() < 1e-4, "packed c={c}");
                assert!((want[c] - got_s[c]).abs() < 1e-4, "sparse c={c}");
            }
        });
    }

    #[test]
    fn vecmat_dead_rows_read_uniform_in_both_layouts() {
        // Row 0 is uniform over 32 columns: at 3 bits every level
        // quantizes to zero (level(1/32 · 7) = 0), so the row is fully
        // auto-pruned. Row 1 keeps real mass. The dead row's input must
        // spread uniformly, matching the dense dequantization.
        let mut m = Mat::zeros(2, 32);
        for c in 0..32 {
            m.set(0, c, 1.0 / 32.0);
        }
        m.set(1, 3, 0.7);
        m.set(1, 9, 0.3);
        let v = [0.4f32, 0.6];
        for (label, got) in [
            ("sparse", {
                let sparse = SparseQMat::from_mat(&m, 3);
                assert_eq!(sparse.row_ptr[1], 0, "row 0 must auto-prune");
                let mut out = vec![0f32; 32];
                sparse.vecmat(&v, &mut out);
                out
            }),
            ("packed", {
                let packed = PackedMat::from_mat(&m, 3);
                let mut out = vec![0f32; 32];
                packed.vecmat(&v, &mut out);
                out
            }),
        ] {
            let dense = SparseQMat::from_mat(&m, 3).to_mat();
            let mut want = vec![0f32; 32];
            dense.vecmat(&v, &mut want);
            for c in 0..32 {
                assert!(
                    (want[c] - got[c]).abs() < 1e-6,
                    "{label} c={c} want={} got={}",
                    want[c],
                    got[c]
                );
            }
        }
    }

    /// A beam panel with exact zeros mixed in (so the per-beam
    /// `vr == 0.0` skip diverges across beams) over `rows` inputs.
    fn beam_panel(rng: &mut Rng, b: usize, rows: usize) -> Vec<f32> {
        (0..b * rows)
            .map(|_| if rng.below(4) == 0 { 0.0 } else { rng.f32() })
            .collect()
    }

    #[test]
    fn vecmat_panel_bit_identical_to_independent_vecmats() {
        // The tentpole kernel invariant, at the unit level: the fused
        // panel is indistinguishable — to the bit — from B independent
        // per-beam calls, across bit widths (3/8/12), panel widths
        // (1/3/8/17), a non-multiple-of-word column count (33 at 3
        // bits: 21 slots/word → partial last word) and rows that fully
        // auto-prune (uniform fallback). FP32 (the "bits=32" cell of
        // the matrix) is covered by the same test on `Mat`.
        Prop::new(12, 0xB417).run("vecmat-panel-bits", |rng, _| {
            let rows = rng.range(3, 19); // often not a multiple of anything
            let m = gen::stochastic_mat(rng, rows, 33);
            let bits = [3u32, 8, 12][rng.below_usize(3)];
            let packed = PackedMat::from_mat(&m, bits);
            let sparse = SparseQMat::from_mat(&m, bits);
            for b in [1usize, 3, 8, 17] {
                let panel = beam_panel(rng, b, rows);
                for (label, fused, per_beam) in [
                    ("sparse", {
                        let mut out = vec![0f32; b * 33];
                        sparse.vecmat_panel(&panel, b, &mut out);
                        out
                    }, {
                        let mut out = vec![0f32; b * 33];
                        for bi in 0..b {
                            sparse.vecmat(
                                &panel[bi * rows..(bi + 1) * rows],
                                &mut out[bi * 33..(bi + 1) * 33],
                            );
                        }
                        out
                    }),
                    ("packed", {
                        let mut out = vec![0f32; b * 33];
                        packed.vecmat_panel(&panel, b, &mut out);
                        out
                    }, {
                        let mut out = vec![0f32; b * 33];
                        for bi in 0..b {
                            packed.vecmat(
                                &panel[bi * rows..(bi + 1) * rows],
                                &mut out[bi * 33..(bi + 1) * 33],
                            );
                        }
                        out
                    }),
                ] {
                    for (i, (f, p)) in fused.iter().zip(per_beam.iter()).enumerate() {
                        assert_eq!(
                            f.to_bits(),
                            p.to_bits(),
                            "{label} bits={bits} b={b} flat={i}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn vecmat_panel_dead_rows_bit_identical() {
        // All-zero (fully auto-pruned) rows: the uniform fallback must
        // fold into each beam exactly as the scalar path does — one
        // guarded rank-1 pass per beam after the accumulation.
        let mut m = Mat::zeros(3, 32);
        for c in 0..32 {
            m.set(0, c, 1.0 / 32.0); // auto-prunes at 3 bits
        }
        m.set(1, 3, 0.7);
        m.set(1, 9, 0.3);
        m.set(2, 0, 1.0);
        let mut rng = Rng::seeded(0xDEAD5);
        for b in [1usize, 3, 8, 17] {
            let panel = beam_panel(&mut rng, b, 3);
            for (label, mats) in [
                ("sparse", {
                    let s = SparseQMat::from_mat(&m, 3);
                    assert_eq!(s.row_ptr[1], 0, "row 0 must auto-prune");
                    let mut fused = vec![0f32; b * 32];
                    s.vecmat_panel(&panel, b, &mut fused);
                    let mut want = vec![0f32; b * 32];
                    for bi in 0..b {
                        s.vecmat(&panel[bi * 3..(bi + 1) * 3], &mut want[bi * 32..(bi + 1) * 32]);
                    }
                    (fused, want)
                }),
                ("packed", {
                    let p = PackedMat::from_mat(&m, 3);
                    let mut fused = vec![0f32; b * 32];
                    p.vecmat_panel(&panel, b, &mut fused);
                    let mut want = vec![0f32; b * 32];
                    for bi in 0..b {
                        p.vecmat(&panel[bi * 3..(bi + 1) * 3], &mut want[bi * 32..(bi + 1) * 32]);
                    }
                    (fused, want)
                }),
            ] {
                let (fused, want) = mats;
                for i in 0..b * 32 {
                    assert_eq!(fused[i].to_bits(), want[i].to_bits(), "{label} b={b} flat={i}");
                }
            }
        }
    }

    #[test]
    fn from_parts_matches_from_mat_and_checks_invariants() {
        let mut rng = Rng::seeded(0xF00D);
        let m = gen::stochastic_mat(&mut rng, 6, 40);
        let a = SparseQMat::from_mat(&m, 8);
        let b = SparseQMat::from_parts(
            6,
            40,
            8,
            a.row_ptr.clone(),
            a.col_idx.clone(),
            a.levels.clone(),
        );
        // The recomputed row scales make every dequantized value (and
        // therefore every vecmat) identical.
        let v = rng.dirichlet_symmetric(6, 1.0);
        let (mut out_a, mut out_b) = (vec![0f32; 40], vec![0f32; 40]);
        a.vecmat(&v, &mut out_a);
        b.vecmat(&v, &mut out_b);
        for c in 0..40 {
            assert_eq!(out_a[c].to_bits(), out_b[c].to_bits(), "c={c}");
        }
        // Empty rows are allowed and read uniform.
        let empty = SparseQMat::from_parts(2, 8, 4, vec![0, 1, 1], vec![3], vec![5]);
        assert_eq!(empty.value(1, 0), 1.0 / 8.0);
        assert!(std::panic::catch_unwind(|| {
            SparseQMat::from_parts(1, 8, 4, vec![0, 1], vec![9], vec![5])
        })
        .is_err(), "out-of-range column must be rejected");
        assert!(std::panic::catch_unwind(|| {
            SparseQMat::from_parts(1, 8, 4, vec![0, 1], vec![3], vec![16])
        })
        .is_err(), "level too wide for bits must be rejected");
    }

    #[test]
    fn sparse_matvec_matches_dense_reference() {
        Prop::new(16, 78).run("sparse-matvec", |rng, _| {
            let m = gen::stochastic_mat(rng, 7, 24);
            let bits = [3u32, 4, 8][rng.below_usize(3)];
            let sparse = SparseQMat::from_mat(&m, bits);
            let dense = sparse.to_mat();
            let v = rng.dirichlet_symmetric(m.cols, 0.7);
            let mut want = vec![0f32; m.rows];
            dense.matvec(&v, &mut want);
            let mut got = vec![0f32; m.rows];
            sparse.matvec(&v, &mut got);
            for r in 0..m.rows {
                assert!(
                    (want[r] - got[r]).abs() < 1e-5,
                    "bits={bits} r={r} want={} got={}",
                    want[r],
                    got[r]
                );
            }
        });
    }

    #[test]
    fn sparse_matvec_all_zero_row_reads_uniform() {
        // A uniform row over many columns quantizes to all-zero levels
        // at 3 bits (level(1/32 · 7) = 0): matvec must fall back to the
        // uniform dequantization, i.e. the mean of v.
        let m = Mat::filled(2, 32, 1.0 / 32.0);
        let sparse = SparseQMat::from_mat(&m, 3);
        assert_eq!(sparse.nnz(), 0, "expected fully auto-pruned rows");
        let mut rng = Rng::seeded(79);
        let v = rng.dirichlet_symmetric(32, 0.5);
        let mut got = vec![0f32; 2];
        sparse.matvec(&v, &mut got);
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / 32.0;
        for &g in &got {
            assert!((g as f64 - mean).abs() < 1e-6, "got={g} mean={mean}");
        }
    }

    #[test]
    fn level_at_and_value_match_dense() {
        let mut rng = Rng::seeded(80);
        let m = Mat::random_stochastic(5, 17, 0.2, &mut rng);
        let sparse = SparseQMat::from_mat(&m, 4);
        let dense = sparse.to_mat();
        for r in 0..5 {
            for c in 0..17 {
                assert_eq!(sparse.level_at(r, c), crate::quant::fixed::level(m.at(r, c), 4));
                assert!((sparse.value(r, c) - dense.at(r, c)).abs() < 1e-6);
            }
        }
        assert_eq!(sparse.resident_bytes(), sparse.nnz() * 6 + (5 + 1) * 4 + 5 * 4);
    }

    #[test]
    fn compression_rate_exceeds_99_percent_on_sparse_rows() {
        let mut rng = Rng::seeded(74);
        // Very spiky rows ≈ trained HMM emission (paper Fig 2: >80% of
        // entries < 1e-5).
        let m = Mat::random_stochastic(64, 1000, 0.01, &mut rng);
        let report = CompressionReport::of(&m, 8);
        assert!(
            report.compression_rate() > 0.97,
            "rate={}",
            report.compression_rate()
        );
        let report3 = CompressionReport::of(&m, 3);
        assert!(report3.compression_rate() > report.compression_rate());
    }

    #[test]
    fn storage_accounting_is_consistent() {
        let mut rng = Rng::seeded(75);
        let m = Mat::random_stochastic(16, 64, 0.5, &mut rng);
        let packed = PackedMat::from_mat(&m, 4);
        assert_eq!(packed.storage_bits(), 16 * 64 * 4);
        let sparse = SparseQMat::from_mat(&m, 4);
        assert!(sparse.storage_bits() >= sparse.nnz() * 4);
    }

    #[test]
    fn level_extraction_matches_fixed_quantizer() {
        let mut rng = Rng::seeded(76);
        let m = Mat::random_stochastic(5, 17, 0.3, &mut rng);
        for bits in [2u32, 3, 5, 8, 12] {
            let packed = PackedMat::from_mat(&m, bits);
            for r in 0..5 {
                for c in 0..17 {
                    assert_eq!(
                        packed.level(r, c),
                        crate::quant::fixed::level(m.at(r, c), bits),
                        "bits={bits}"
                    );
                }
            }
        }
    }
}
