//! The bench-regression gate: diff two bench-trajectory artifacts
//! (`BENCH_tables.json` / `BENCH_decode.json`) and flag slowdowns.
//!
//! Each artifact is `{bench, quick, scenarios: [..]}` where every
//! scenario object mixes *identity* fields (hidden, bits, alpha, …)
//! with *timing* fields (`*_ms`, plus the derived `speedup`). The gate
//! matches scenarios across runs by their identity fields — so adding,
//! removing or re-parameterizing scenarios never fails the gate, only
//! a matched scenario getting slower does — and reports a regression
//! when any timing field exceeds the previous run's by more than the
//! threshold (CI uses 25%). Runs at different scales (`quick` flag
//! mismatch) are incomparable and skip cleanly.
//!
//! Used by `src/bin/bench_gate.rs` in the CI bench-smoke job, which
//! downloads the previous run's artifact and fails the job on any
//! regression — the trajectory bites instead of just accumulating.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Fields that carry measurements rather than scenario identity —
/// timings, derived ratios, and *measured model properties* (sparsity,
/// table size). Measured floats must stay out of the match key: a
/// last-ulp shift from an unrelated change would silently unmatch
/// every scenario and turn the gate into a no-op.
fn is_measured_field(key: &str) -> bool {
    key.ends_with("_ms") || key.ends_with("_kb") || key == "speedup" || key == "sparsity"
}

/// The identity of one scenario: its configured (non-measured) fields,
/// canonically serialized (object keys are sorted, so this is
/// deterministic).
fn scenario_key(scenario: &Json) -> Option<String> {
    match scenario {
        Json::Obj(map) => {
            let identity: BTreeMap<String, Json> = map
                .iter()
                .filter(|(k, _)| !is_measured_field(k))
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
            Some(Json::Obj(identity).to_string())
        }
        _ => None,
    }
}

/// One timing field of one matched scenario that got slower than the
/// threshold allows.
#[derive(Clone, Debug)]
pub struct Regression {
    /// Canonical identity of the scenario (its non-timing fields).
    pub scenario: String,
    /// The timing field that regressed (e.g. `sparse_ms`).
    pub field: String,
    /// Previous run's value, milliseconds.
    pub prev_ms: f64,
    /// Current run's value, milliseconds.
    pub cur_ms: f64,
}

impl Regression {
    /// Slowdown ratio (current / previous).
    pub fn ratio(&self) -> f64 {
        self.cur_ms / self.prev_ms.max(1e-12)
    }
}

/// What the gate found when diffing two artifacts.
#[derive(Clone, Debug, Default)]
pub struct GateReport {
    /// Matched scenarios compared field-by-field.
    pub compared: usize,
    /// Current scenarios with no counterpart in the previous run.
    pub unmatched: usize,
    /// Timing fields beyond the slowdown threshold.
    pub regressions: Vec<Regression>,
    /// Human-readable notes (scale mismatch, best improvement, …).
    pub notes: Vec<String>,
}

impl GateReport {
    /// True when no matched timing field regressed.
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// Diff `cur` against `prev`, flagging any matched timing field where
/// `cur > prev · (1 + threshold)`. Returns `Err` only for artifacts
/// the gate cannot read (missing/NaN fields are skipped, not errors:
/// a malformed *previous* artifact must not wedge the pipeline).
pub fn gate(prev: &Json, cur: &Json, threshold: f64) -> Result<GateReport, String> {
    let mut report = GateReport::default();
    let cur_scenarios = cur
        .get("scenarios")
        .and_then(Json::as_arr)
        .ok_or("current artifact has no scenarios array")?;
    let prev_scenarios = match prev.get("scenarios").and_then(Json::as_arr) {
        Some(s) => s,
        None => {
            report
                .notes
                .push("previous artifact has no scenarios array — nothing to compare".into());
            report.unmatched = cur_scenarios.len();
            return Ok(report);
        }
    };
    if prev.get("bench") != cur.get("bench") {
        return Err(format!(
            "artifact mismatch: previous is {:?}, current is {:?}",
            prev.get("bench"),
            cur.get("bench")
        ));
    }
    if prev.get("quick").and_then(Json::as_bool) != cur.get("quick").and_then(Json::as_bool) {
        report
            .notes
            .push("quick-mode mismatch between runs — scales are incomparable, skipping".into());
        report.unmatched = cur_scenarios.len();
        return Ok(report);
    }

    let mut prev_by_key: BTreeMap<String, &Json> = BTreeMap::new();
    for s in prev_scenarios {
        if let Some(k) = scenario_key(s) {
            prev_by_key.insert(k, s);
        }
    }

    let mut best_improvement: Option<(String, f64)> = None;
    for scenario in cur_scenarios {
        let key = match scenario_key(scenario) {
            Some(k) => k,
            None => continue,
        };
        let Some(prev_scenario) = prev_by_key.get(&key) else {
            report.unmatched += 1;
            continue;
        };
        report.compared += 1;
        let Json::Obj(fields) = scenario else { continue };
        for (field, value) in fields.iter().filter(|(k, _)| k.ends_with("_ms")) {
            let (Some(cur_ms), Some(prev_ms)) = (
                value.as_f64(),
                prev_scenario.get(field).and_then(Json::as_f64),
            ) else {
                continue;
            };
            if !cur_ms.is_finite() || !prev_ms.is_finite() || prev_ms <= 0.0 {
                continue;
            }
            if cur_ms > prev_ms * (1.0 + threshold) {
                report.regressions.push(Regression {
                    scenario: key.clone(),
                    field: field.clone(),
                    prev_ms,
                    cur_ms,
                });
            } else if cur_ms < prev_ms {
                let gain = prev_ms / cur_ms.max(1e-12);
                let better = match &best_improvement {
                    Some((_, g)) => gain > *g,
                    None => true,
                };
                if better {
                    best_improvement = Some((format!("{key} {field}"), gain));
                }
            }
        }
    }
    if let Some((what, gain)) = best_improvement {
        report
            .notes
            .push(format!("best improvement: {what} {gain:.2}x faster"));
    }
    // Both runs have scenarios but none matched: the baseline is
    // incomparable (identity fields changed wholesale). Say so loudly —
    // a gate that silently compares nothing reads as green.
    if report.compared == 0 && !cur_scenarios.is_empty() && !prev_scenarios.is_empty() {
        report.notes.push(format!(
            "WARNING: 0 of {} scenario(s) matched the baseline — identity fields changed; \
             the gate checked nothing this run",
            cur_scenarios.len()
        ));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(quick: bool, scenarios: Vec<Json>) -> Json {
        Json::obj(vec![
            ("bench", Json::str("decode")),
            ("quick", Json::Bool(quick)),
            ("scenarios", Json::arr(scenarios)),
        ])
    }

    fn scenario(hidden: f64, bits: f64, dense_ms: f64, sparse_ms: f64) -> Json {
        Json::obj(vec![
            ("hidden", Json::num(hidden)),
            ("bits", Json::num(bits)),
            ("dense_ms", Json::num(dense_ms)),
            ("sparse_ms", Json::num(sparse_ms)),
            ("speedup", Json::num(dense_ms / sparse_ms)),
        ])
    }

    #[test]
    fn unchanged_runs_pass() {
        let a = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let report = gate(&a, &a, 0.25).unwrap();
        assert!(report.passed());
        assert_eq!(report.compared, 1);
        assert_eq!(report.unmatched, 0);
    }

    #[test]
    fn slowdown_beyond_threshold_is_a_regression() {
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let cur = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.6)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert!(!report.passed());
        assert_eq!(report.regressions.len(), 1);
        let r = &report.regressions[0];
        assert_eq!(r.field, "sparse_ms");
        assert!((r.ratio() - 1.3).abs() < 1e-9);
    }

    #[test]
    fn slowdown_within_threshold_passes() {
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let cur = artifact(true, vec![scenario(64.0, 8.0, 11.0, 2.4)]);
        assert!(gate(&prev, &cur, 0.25).unwrap().passed());
    }

    #[test]
    fn speedup_field_is_never_gated() {
        // speedup is derived from the ms fields; a *rising* speedup
        // (sparse got faster) must not read as a regression.
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 4.0)]);
        let cur = artifact(true, vec![scenario(64.0, 8.0, 10.0, 1.0)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert!(report.passed());
    }

    #[test]
    fn measured_fields_do_not_break_scenario_matching() {
        // sparsity/table_kb are measured, not configured: a last-ulp
        // drift must not unmatch the scenario (which would turn the
        // gate into a silent no-op), and the timing comparison must
        // still fire.
        let with_sparsity = |sparsity: f64, sparse_ms: f64| {
            Json::obj(vec![
                ("hidden", Json::num(64.0)),
                ("bits", Json::num(8.0)),
                ("sparsity", Json::num(sparsity)),
                ("table_kb", Json::num(112.0 + sparsity)),
                ("sparse_ms", Json::num(sparse_ms)),
            ])
        };
        let prev = artifact(true, vec![with_sparsity(0.9231, 2.0)]);
        let cur = artifact(true, vec![with_sparsity(0.9230, 2.6)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert_eq!(report.compared, 1, "sparsity drift must not unmatch");
        assert_eq!(report.regressions.len(), 1);
    }

    #[test]
    fn fully_unmatched_runs_warn_loudly() {
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let cur = artifact(true, vec![scenario(96.0, 3.0, 10.0, 2.0)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert_eq!(report.compared, 0);
        assert!(
            report.notes.iter().any(|n| n.contains("WARNING")),
            "a gate that compared nothing must say so: {:?}",
            report.notes
        );
    }

    #[test]
    fn reparameterized_scenarios_skip_instead_of_failing() {
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let cur = artifact(true, vec![scenario(96.0, 8.0, 99.0, 99.0)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert!(report.passed());
        assert_eq!(report.compared, 0);
        assert_eq!(report.unmatched, 1);
    }

    #[test]
    fn quick_mode_mismatch_skips_cleanly() {
        let prev = artifact(false, vec![scenario(64.0, 8.0, 1.0, 1.0)]);
        let cur = artifact(true, vec![scenario(64.0, 8.0, 99.0, 99.0)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert!(report.passed());
        assert_eq!(report.compared, 0);
    }

    #[test]
    fn different_benches_refuse_to_compare() {
        let mut prev = artifact(true, vec![]);
        if let Json::Obj(m) = &mut prev {
            m.insert("bench".into(), Json::str("tables"));
        }
        let cur = artifact(true, vec![]);
        assert!(gate(&prev, &cur, 0.25).is_err());
    }

    #[test]
    fn round_trips_through_serialization() {
        // The gate consumes artifacts exactly as the benches write
        // them: serialize, reparse, diff.
        let prev =
            artifact(true, vec![scenario(64.0, 3.0, 8.0, 1.5), scenario(64.0, 8.0, 9.0, 2.0)]);
        let cur =
            artifact(true, vec![scenario(64.0, 3.0, 8.1, 3.0), scenario(64.0, 8.0, 9.0, 2.0)]);
        let prev = Json::parse(&prev.to_string()).unwrap();
        let cur = Json::parse(&cur.to_string()).unwrap();
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert_eq!(report.compared, 2);
        assert_eq!(report.regressions.len(), 1, "{:?}", report.regressions);
        assert_eq!(report.regressions[0].field, "sparse_ms");
    }
}
