//! Foundation utilities owned by this repository (the default build has
//! zero external dependencies — `xla`/`anyhow` exist only behind the
//! `pjrt` feature — so JSON, CLI parsing, RNG, thread pools, timing and
//! property testing are implemented here).

pub mod benchgate;
pub mod cli;
pub mod json;
pub mod kernel;
pub mod logging;
pub mod mat;
pub mod proptest;
pub mod rng;
pub mod threadpool;
pub mod timer;
