//! Compression-method shoot-out: every method the paper evaluates
//! (pruning, integer, k-means, fixed-point, Norm-Q) across bit widths,
//! on the same trained HMM — the condensed version of Tables I/II/III/V.
//!
//! Run: cargo run --release --example compression_sweep [-- --items 100]

use normq::eval::evaluate;
use normq::quant::Method;
use normq::tables::ExperimentContext;
use normq::util::cli::Args;

fn main() {
    normq::util::logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, ExperimentContext::VALUE_KEYS).expect("bad args");
    let ctx = ExperimentContext::build(&args).expect("context");

    let methods = vec![
        Method::Fp32,
        Method::Prune { ratio: 0.85, renorm: false },
        Method::Prune { ratio: 0.95, renorm: true },
        Method::Integer { bits: 8 },
        Method::Kmeans { bits: 8, renorm: false },
        Method::Kmeans { bits: 8, renorm: true },
        Method::Fixed { bits: 8 },
        Method::NormQ { bits: 8 },
        Method::NormQ { bits: 4 },
        Method::NormQ { bits: 3 },
        Method::NormQ { bits: 2 },
    ];
    println!(
        "{:<22} {:>8} {:>7} {:>7} {:>7} {:>7}",
        "method", "Success", "Rouge", "BLEU4", "CIDEr", "SPICE*"
    );
    for m in methods {
        let hmm = m.apply(&ctx.hmm);
        let (s, _) = evaluate(&ctx.lm, &hmm, &ctx.corpus, &ctx.items, &ctx.decode, ctx.threads);
        println!(
            "{:<22} {:>8.1} {:>7.1} {:>7.1} {:>7.2} {:>7.1}",
            m.label(),
            s.success_rate * 100.0,
            s.rouge * 100.0,
            s.bleu4 * 100.0,
            s.cider * 100.0,
            s.spice * 100.0
        );
    }
}
