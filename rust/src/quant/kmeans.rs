//! 1-D k-means codebook quantization — the clustering baseline the paper
//! evaluates (§III-B, Table III: "Direct K-means" and "K-means during
//! EM"). K = 2^b floating-point centroids form a stored cookbook; every
//! weight is replaced by its nearest centroid.
//!
//! For one-dimensional data, Lloyd's algorithm with sorted data and
//! boundary bisection converges quickly; we use kmeans++ style seeding by
//! quantiles for determinism.

use crate::hmm::Hmm;
use crate::util::mat::Mat;

/// A 1-D k-means codebook.
#[derive(Clone, Debug)]
pub struct KmeansCodebook {
    /// Centroid values, sorted ascending.
    pub centroids: Vec<f32>,
}

impl KmeansCodebook {
    /// Fit `k` centroids to `data` with at most `iters` Lloyd iterations.
    /// Deterministic: seeds at evenly spaced quantiles of the sorted data.
    pub fn fit(data: &[f32], k: usize, iters: usize) -> KmeansCodebook {
        assert!(k >= 1);
        let mut sorted: Vec<f32> = data.iter().copied().filter(|v| v.is_finite()).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if sorted.is_empty() {
            return KmeansCodebook { centroids: vec![0.0; k] };
        }
        let n = sorted.len();
        // Quantile seeding.
        let mut centroids: Vec<f32> = (0..k)
            .map(|i| sorted[((i as f64 + 0.5) / k as f64 * n as f64) as usize % n])
            .collect();
        centroids.dedup();
        while centroids.len() < k {
            // Re-pad duplicates (heavily-tied data, e.g. many zeros).
            let last = *centroids.last().unwrap();
            centroids.push(last + (centroids.len() as f32) * f32::EPSILON.max(1e-12));
        }
        let mut sums = vec![0f64; k];
        let mut counts = vec![0usize; k];
        for _ in 0..iters {
            sums.iter_mut().for_each(|s| *s = 0.0);
            counts.iter_mut().for_each(|c| *c = 0);
            // Assignment via boundary scan (centroids sorted).
            let mut c = 0usize;
            for &v in &sorted {
                while c + 1 < k && (centroids[c + 1] - v).abs() <= (centroids[c] - v).abs() {
                    c += 1;
                }
                // v may belong to an earlier centroid if data not visited
                // monotonically — but sorted data + sorted centroids keep
                // assignment monotone, so this is exact.
                sums[c] += v as f64;
                counts[c] += 1;
            }
            let mut moved = 0f64;
            for i in 0..k {
                if counts[i] > 0 {
                    let next = (sums[i] / counts[i] as f64) as f32;
                    moved += (next - centroids[i]).abs() as f64;
                    centroids[i] = next;
                }
            }
            centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if moved < 1e-9 {
                break;
            }
        }
        KmeansCodebook { centroids }
    }

    /// Nearest centroid index (binary search on the sorted centroids).
    #[inline]
    pub fn assign(&self, v: f32) -> usize {
        let cs = &self.centroids;
        match cs.binary_search_by(|c| c.partial_cmp(&v).unwrap()) {
            Ok(i) => i,
            Err(i) => {
                if i == 0 {
                    0
                } else if i >= cs.len() {
                    cs.len() - 1
                } else if (v - cs[i - 1]).abs() <= (cs[i] - v).abs() {
                    i - 1
                } else {
                    i
                }
            }
        }
    }

    /// Snap a value to its nearest centroid.
    #[inline]
    pub fn qdq(&self, v: f32) -> f32 {
        self.centroids[self.assign(v)]
    }

    /// Stored cookbook bytes (fp32 centroids) — counted by the
    /// compression-rate accounting in `packed.rs`.
    pub fn storage_bytes(&self) -> usize {
        self.centroids.len() * 4
    }
}

/// Replace every entry of `m` with its nearest centroid (codebook fitted
/// on `m` itself). Returns the codebook. "Direct K-means" of Table III.
pub fn kmeans_mat(m: &mut Mat, bits: u32, iters: usize) -> KmeansCodebook {
    let cb = KmeansCodebook::fit(&m.data, 1usize << bits, iters);
    for v in m.data.iter_mut() {
        *v = cb.qdq(*v);
    }
    cb
}

/// K-means quantize a whole HMM; with `normalize`, rows are re-normalized
/// afterwards ("normalized K-means", the variant run inside K-means-aware
/// EM in Table III / Fig 5d).
pub fn kmeans_hmm(hmm: &Hmm, bits: u32, iters: usize, normalize: bool, eps: f64) -> Hmm {
    let mut out = hmm.clone();
    kmeans_mat(&mut out.trans, bits, iters);
    kmeans_mat(&mut out.emit, bits, iters);
    let cb = KmeansCodebook::fit(&out.init, 1usize << bits.min(8), iters);
    for v in out.init.iter_mut() {
        *v = cb.qdq(*v);
    }
    if normalize {
        out.renormalize(eps);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{gen, Prop};
    use crate::util::rng::Rng;

    #[test]
    fn centroids_sorted_and_sized() {
        let data: Vec<f32> = (0..1000).map(|i| (i % 97) as f32 / 97.0).collect();
        let cb = KmeansCodebook::fit(&data, 16, 30);
        assert_eq!(cb.centroids.len(), 16);
        for w in cb.centroids.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn assign_picks_nearest() {
        let cb = KmeansCodebook { centroids: vec![0.0, 0.5, 1.0] };
        assert_eq!(cb.assign(0.1), 0);
        assert_eq!(cb.assign(0.3), 1);
        assert_eq!(cb.assign(0.74), 1);
        assert_eq!(cb.assign(0.76), 2);
        assert_eq!(cb.assign(-5.0), 0);
        assert_eq!(cb.assign(5.0), 2);
    }

    #[test]
    fn kmeans_reduces_distortion_vs_two_point() {
        let mut rng = Rng::seeded(51);
        let data: Vec<f32> = (0..2000).map(|_| rng.f32()).collect();
        let cb16 = KmeansCodebook::fit(&data, 16, 30);
        let cb2 = KmeansCodebook::fit(&data, 2, 30);
        let mse = |cb: &KmeansCodebook| {
            data.iter()
                .map(|&v| {
                    let d = (v - cb.qdq(v)) as f64;
                    d * d
                })
                .sum::<f64>()
        };
        assert!(mse(&cb16) < mse(&cb2) / 4.0);
    }

    #[test]
    fn qdq_is_idempotent() {
        Prop::default().run("kmeans-idempotent", |rng, _| {
            let data: Vec<f32> = (0..300).map(|_| rng.f32()).collect();
            let cb = KmeansCodebook::fit(&data, 8, 20);
            let v = rng.f32();
            let once = cb.qdq(v);
            assert_eq!(once, cb.qdq(once));
        });
    }

    #[test]
    fn heavy_zero_mass_keeps_a_zero_centroid() {
        // HMM-like data: 90% zeros. K-means must park a centroid at ~0.
        let mut data = vec![0f32; 900];
        data.extend((0..100).map(|i| 0.5 + i as f32 / 200.0));
        let cb = KmeansCodebook::fit(&data, 4, 30);
        assert!(cb.centroids[0].abs() < 1e-3, "c0={}", cb.centroids[0]);
    }

    #[test]
    fn kmeans_hmm_normalized_is_valid() {
        Prop::new(8, 52).run("kmeans-hmm-valid", |rng, _| {
            let m = gen::stochastic_mat(rng, 6, 20);
            let hmm = Hmm {
                init: rng.dirichlet_symmetric(6, 1.0),
                trans: gen::stochastic_mat(rng, 6, 6),
                emit: m,
            };
            // fix shapes: trans must be 6x6 — regenerate deterministically
            let hmm = Hmm {
                init: hmm.init.clone(),
                trans: crate::util::mat::Mat::random_stochastic(6, 6, 0.5, rng),
                emit: crate::util::mat::Mat::random_stochastic(6, 20, 0.2, rng),
            };
            let q = kmeans_hmm(&hmm, 4, 15, true, 1e-12);
            assert!(q.is_valid(1e-3));
        });
    }
}
