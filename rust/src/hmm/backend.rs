//! The model-backend abstraction behind the constraint-table engine
//! *and* the decode beam loop.
//!
//! The two hot consumers of HMM weights touch the model through a
//! small, fixed set of operations:
//!
//! - `ConstraintTable::build_with` needs the hidden-state count, a
//!   backward transition step (`out[h] = Σ_h' trans[h][h'] · v[h']`),
//!   the emission *columns* of the DFA exception tokens, and the
//!   stored non-zero counts (the engine's parallelism cost model);
//! - `generate::decode_with_table` additionally needs the initial
//!   belief, the per-step acceptance product `w = u @ emit` (the
//!   `(1×H)·(H×V)` decode hot spot), single emission entries for the
//!   exception/EOS corrections, and the fused forward step (emission
//!   column gather + `v @ trans`).
//!
//! That union is the whole trait. Two implementations exist:
//!
//! - the dense FP32 [`Hmm`] (this module's impl), paying O(H²) per
//!   transition step and O(H·V) per acceptance product; and
//! - a quantized model stored as non-zero levels only
//!   ([`crate::quant::qhmm::QuantizedHmm`]), paying O(nnz) — after
//!   Norm-Q at b ≤ 8 the overwhelming majority of levels are zero
//!   (the ≥99% compression of the paper's Table IV), so the same
//!   recursions run an order of magnitude less work and the serving
//!   path never materializes dense FP32 weights, on the table build
//!   *or* in the beam loop.
//!
//! The trait deliberately exposes *column* non-zeros for `emit`: the
//! table recursion touches emissions only at exception tokens (the
//! keyword alphabet), one column per token, while it consumes `trans`
//! row-by-row through the matvec.
//!
//! All-zero rows (fully auto-pruned by quantization) dequantize to
//! *uniform* in every operation here, matching
//! [`crate::quant::packed::SparseQMat::to_mat`] — so a sparse backend
//! and the dense materialization of the same levels agree within
//! float-path tolerance everywhere, which `tests/decode_equivalence.rs`
//! property-tests end to end.

use crate::hmm::Hmm;
use crate::util::kernel::KernelScratch;

/// Read-only model access for the HMM×DFA table recursion and the
/// decode beam loop; see the [module docs](self).
pub trait HmmBackend: Send + Sync {
    /// Hidden state count H.
    fn hidden(&self) -> usize;

    /// Vocabulary size V.
    fn vocab(&self) -> usize;

    /// γ: the initial state distribution, length H — the belief every
    /// beam starts from.
    fn init(&self) -> &[f32];

    /// One backward transition step: `out[h] = Σ_h' P(h'|h) · v[h']`
    /// (`trans @ v` with f64 accumulation). Sparse backends iterate
    /// stored non-zeros only.
    fn trans_matvec(&self, v: &[f32], out: &mut [f32]);

    /// One forward transition step: `out[h'] = Σ_h v[h] · P(h'|h)`
    /// (`v @ trans` with f64 accumulation) — the belief-advance half of
    /// [`HmmBackend::forward_step`].
    fn trans_vecmat(&self, v: &[f32], out: &mut [f32]);

    /// The decode hot spot: `out[x] = Σ_h u[h] · P(x|h)` (`u @ emit`
    /// with f64 accumulation), scoring every token's acceptance weight
    /// in one sweep. Sparse backends pay O(nnz of the rows with
    /// `u[h] ≠ 0`) instead of O(H·V).
    fn emit_vecmat(&self, u: &[f32], out: &mut [f32]);

    /// Single emission entry `P(tok|h)` — the exception-token and EOS
    /// corrections read a handful of these per beam step. All-zero
    /// quantized rows read as uniform `1/V`.
    fn emit_at(&self, h: usize, tok: usize) -> f32;

    /// Non-zeros of emission column `tok`, as `(h, P(tok|h))` sorted by
    /// `h`. The table build extracts one column per distinct DFA
    /// exception token, once per build.
    fn emit_col(&self, tok: usize) -> Vec<(u32, f32)>;

    /// Stored non-zero counts `(trans, emit)` — the sparsity the table
    /// engine's cost model and the benches report.
    fn nnz(&self) -> (usize, usize);

    /// One fused forward step: observe `tok` under belief `alpha` (the
    /// predictive P(z_t | x_{<t})) and advance:
    ///
    ///   weighted[h] = alpha[h] · emit[h, tok]
    ///   scale       = Σ_h weighted[h]          (= P(x_t | x_{<t}))
    ///   next[h']    = Σ_h (weighted[h]/scale) · trans[h, h']
    ///
    /// Returns the scale. Scales below ~1e-30 are "effectively
    /// impossible": the model gives this token no real mass (the
    /// paper's garbled-output failure mode after over-pruning or
    /// quantization). They are also numerically toxic — `1/scale`
    /// overflows f32 and poisons the belief with `inf·0 = NaN` (caught
    /// by `tests/robustness.rs`) — so the belief uniform-resets and the
    /// step reports 0.
    fn forward_step(&self, alpha: &[f32], tok: usize, next: &mut [f32]) -> f64 {
        let h_n = self.hidden();
        debug_assert_eq!(alpha.len(), h_n);
        debug_assert_eq!(next.len(), h_n);
        debug_assert!(tok < self.vocab());
        let mut weighted = vec![0f32; h_n];
        let mut scale = 0f64;
        for (h, w) in weighted.iter_mut().enumerate() {
            let p = alpha[h] as f64 * self.emit_at(h, tok) as f64;
            *w = p as f32;
            scale += p;
        }
        if scale <= 1e-30 {
            let u = 1.0 / h_n as f32;
            for n in next.iter_mut() {
                *n = u;
            }
            return 0.0;
        }
        let inv = (1.0 / scale) as f32;
        for w in weighted.iter_mut() {
            *w *= inv;
        }
        self.trans_vecmat(&weighted, next);
        scale
    }

    /// Panel form of [`HmmBackend::emit_vecmat`]: score `b` beams'
    /// acceptance weights in one fused sweep. `u` holds `b` belief
    /// products back to back (`u[bi·H .. (bi+1)·H]`), `out` receives
    /// the `b` weight vectors in the same layout over V.
    ///
    /// The default implementation loops the per-beam op, so the trait
    /// stays object-safe and every existing backend keeps working
    /// unchanged; [`Hmm`] and [`crate::quant::qhmm::QuantizedHmm`]
    /// override it with blocked panel kernels that stream the weight
    /// arrays once per *panel* instead of once per beam. Either way
    /// the result is bit-identical to `b` per-beam calls — the batched
    /// decode engine relies on that.
    fn emit_panel(&self, u: &[f32], b: usize, out: &mut [f32]) {
        let h_n = self.hidden();
        let v_n = self.vocab();
        debug_assert_eq!(u.len(), b * h_n);
        debug_assert_eq!(out.len(), b * v_n);
        for bi in 0..b {
            self.emit_vecmat(&u[bi * h_n..(bi + 1) * h_n], &mut out[bi * v_n..(bi + 1) * v_n]);
        }
    }

    /// [`HmmBackend::emit_panel`] with caller-owned [`KernelScratch`]:
    /// the scratch carries the accumulator panel and lane tables (so
    /// the steady-state decode loop allocates nothing) plus the
    /// intra-step thread budget the blocked kernels may fan out over.
    /// The default ignores the scratch and loops the per-beam op —
    /// results are bit-identical either way.
    fn emit_panel_with(&self, u: &[f32], b: usize, out: &mut [f32], scratch: &mut KernelScratch) {
        let _ = scratch;
        self.emit_panel(u, b, out);
    }

    /// Panel form of [`HmmBackend::trans_vecmat`]: advance `b` beams'
    /// beliefs in one fused sweep (same back-to-back layout as
    /// [`HmmBackend::emit_panel`], H in and H out). Default loops the
    /// per-beam op; overrides must stay bit-identical to it.
    fn trans_panel(&self, v: &[f32], b: usize, out: &mut [f32]) {
        let h_n = self.hidden();
        debug_assert_eq!(v.len(), b * h_n);
        debug_assert_eq!(out.len(), b * h_n);
        for bi in 0..b {
            self.trans_vecmat(&v[bi * h_n..(bi + 1) * h_n], &mut out[bi * h_n..(bi + 1) * h_n]);
        }
    }

    /// [`HmmBackend::trans_panel`] with caller-owned [`KernelScratch`]
    /// (see [`HmmBackend::emit_panel_with`]). The default ignores the
    /// scratch and loops the per-beam op.
    fn trans_panel_with(&self, v: &[f32], b: usize, out: &mut [f32], scratch: &mut KernelScratch) {
        let _ = scratch;
        self.trans_panel(v, b, out);
    }

    /// Panel form of [`HmmBackend::forward_step`]: observe `toks[bi]`
    /// under belief `alphas[bi·H .. (bi+1)·H]` and advance all `b`
    /// beams at once. `next` receives the advanced beliefs in the same
    /// layout; `scales[bi]` gets each beam's per-step scale (0.0 for
    /// the uniform-reset case, exactly like the scalar op).
    ///
    /// This default is already fused: it reproduces
    /// [`HmmBackend::forward_step`]'s emission-weighting arithmetic
    /// per beam verbatim — including the `scale <= 1e-30`
    /// uniform-reset guard, which never touches the transition matrix
    /// — then compacts the surviving beams into one panel for a single
    /// [`HmmBackend::trans_panel`] call. A backend therefore only
    /// needs to override `trans_panel` (and `emit_panel`) to run the
    /// whole batched forward step through its blocked kernels.
    fn forward_step_panel(
        &self,
        alphas: &[f32],
        toks: &[usize],
        next: &mut [f32],
        scales: &mut [f64],
    ) {
        self.forward_step_panel_with(alphas, toks, next, scales, &mut KernelScratch::new());
    }

    /// [`HmmBackend::forward_step_panel`] with caller-owned
    /// [`KernelScratch`]: the emission-weighting staging buffers
    /// (weighted panel, live-lane list, compaction panels) live in the
    /// scratch and the transition advance runs through
    /// [`HmmBackend::trans_panel_with`], so a decode worker holding one
    /// scratch performs the whole fused forward step without
    /// allocating. Arithmetic, guard and ordering are exactly the
    /// scalar [`HmmBackend::forward_step`]'s, per beam.
    fn forward_step_panel_with(
        &self,
        alphas: &[f32],
        toks: &[usize],
        next: &mut [f32],
        scales: &mut [f64],
        scratch: &mut KernelScratch,
    ) {
        let h_n = self.hidden();
        let b = toks.len();
        debug_assert_eq!(alphas.len(), b * h_n);
        debug_assert_eq!(next.len(), b * h_n);
        debug_assert_eq!(scales.len(), b);
        // The staging buffers move out of the scratch for the duration
        // of the call (the scratch itself is re-borrowed by the nested
        // trans_panel_with) and back in before returning.
        let mut weighted = std::mem::take(&mut scratch.weighted);
        let mut live = std::mem::take(&mut scratch.live);
        weighted.clear();
        weighted.resize(b * h_n, 0.0);
        live.clear();
        for bi in 0..b {
            debug_assert!(toks[bi] < self.vocab());
            let alpha = &alphas[bi * h_n..(bi + 1) * h_n];
            let wrow = &mut weighted[bi * h_n..(bi + 1) * h_n];
            let mut scale = 0f64;
            for (h, w) in wrow.iter_mut().enumerate() {
                let p = alpha[h] as f64 * self.emit_at(h, toks[bi]) as f64;
                *w = p as f32;
                scale += p;
            }
            if scale <= 1e-30 {
                let u = 1.0 / h_n as f32;
                for n in next[bi * h_n..(bi + 1) * h_n].iter_mut() {
                    *n = u;
                }
                scales[bi] = 0.0;
                continue;
            }
            let inv = (1.0 / scale) as f32;
            for w in wrow.iter_mut() {
                *w *= inv;
            }
            scales[bi] = scale;
            live.push(bi);
        }
        if live.is_empty() {
            scratch.weighted = weighted;
            scratch.live = live;
            return;
        }
        if live.len() == b {
            self.trans_panel_with(&weighted, b, next, scratch);
            scratch.weighted = weighted;
            scratch.live = live;
            return;
        }
        // Compact the surviving beams so the panel kernel sees a dense
        // panel; scatter the advanced beliefs back to their lanes.
        let mut panel = std::mem::take(&mut scratch.compact_in);
        panel.clear();
        for &bi in live.iter() {
            panel.extend_from_slice(&weighted[bi * h_n..(bi + 1) * h_n]);
        }
        let mut out = std::mem::take(&mut scratch.compact_out);
        out.clear();
        out.resize(live.len() * h_n, 0.0);
        self.trans_panel_with(&panel, live.len(), &mut out, scratch);
        for (i, &bi) in live.iter().enumerate() {
            next[bi * h_n..(bi + 1) * h_n].copy_from_slice(&out[i * h_n..(i + 1) * h_n]);
        }
        scratch.weighted = weighted;
        scratch.live = live;
        scratch.compact_in = panel;
        scratch.compact_out = out;
    }
}

/// The dense FP32 model is its own backend: every entry is "stored",
/// so `nnz` counts exact zeros and each product is the plain dense
/// loop.
impl HmmBackend for Hmm {
    fn hidden(&self) -> usize {
        Hmm::hidden(self)
    }

    fn vocab(&self) -> usize {
        Hmm::vocab(self)
    }

    fn init(&self) -> &[f32] {
        &self.init
    }

    fn trans_matvec(&self, v: &[f32], out: &mut [f32]) {
        self.trans.matvec(v, out);
    }

    fn trans_vecmat(&self, v: &[f32], out: &mut [f32]) {
        self.trans.vecmat(v, out);
    }

    fn emit_vecmat(&self, u: &[f32], out: &mut [f32]) {
        self.emit.vecmat(u, out);
    }

    fn emit_at(&self, h: usize, tok: usize) -> f32 {
        self.emit.at(h, tok)
    }

    fn emit_col(&self, tok: usize) -> Vec<(u32, f32)> {
        (0..Hmm::hidden(self))
            .filter_map(|h| {
                let e = self.emit.at(h, tok);
                (e != 0.0).then_some((h as u32, e))
            })
            .collect()
    }

    fn nnz(&self) -> (usize, usize) {
        (
            self.trans.data.len() - self.trans.zero_count(),
            self.emit.data.len() - self.emit.zero_count(),
        )
    }

    fn emit_panel(&self, u: &[f32], b: usize, out: &mut [f32]) {
        self.emit.vecmat_panel(u, b, out);
    }

    fn trans_panel(&self, v: &[f32], b: usize, out: &mut [f32]) {
        self.trans.vecmat_panel(v, b, out);
    }

    fn emit_panel_with(&self, u: &[f32], b: usize, out: &mut [f32], scratch: &mut KernelScratch) {
        self.emit.vecmat_panel_with(u, b, out, scratch);
    }

    fn trans_panel_with(&self, v: &[f32], b: usize, out: &mut [f32], scratch: &mut KernelScratch) {
        self.trans.vecmat_panel_with(v, b, out, scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_backend_mirrors_the_model() {
        let mut rng = Rng::seeded(11);
        let mut hmm = Hmm::random(6, 14, 0.3, 0.2, &mut rng);
        assert_eq!(HmmBackend::hidden(&hmm), 6);
        let (t0, e0) = HmmBackend::nnz(&hmm);
        assert_eq!(t0, 6 * 6 - hmm.trans.zero_count());
        assert_eq!(e0, 6 * 14 - hmm.emit.zero_count());
        // Zeroing an entry must drop the transition nnz by one.
        let before = hmm.trans.at(0, 1);
        if before != 0.0 {
            hmm.trans.set(0, 1, 0.0);
            assert_eq!(HmmBackend::nnz(&hmm).0, t0 - 1);
        }
    }

    #[test]
    fn dense_trans_matvec_matches_mat() {
        let mut rng = Rng::seeded(12);
        let hmm = Hmm::random(5, 9, 0.5, 0.5, &mut rng);
        let v = rng.dirichlet_symmetric(5, 1.0);
        let mut want = vec![0f32; 5];
        hmm.trans.matvec(&v, &mut want);
        let mut got = vec![0f32; 5];
        HmmBackend::trans_matvec(&hmm, &v, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn dense_decode_ops_mirror_the_matrices() {
        let mut rng = Rng::seeded(14);
        let hmm = Hmm::random(6, 11, 0.4, 0.4, &mut rng);
        assert_eq!(HmmBackend::vocab(&hmm), 11);
        assert_eq!(HmmBackend::init(&hmm), &hmm.init[..]);
        assert_eq!(HmmBackend::emit_at(&hmm, 2, 7), hmm.emit.at(2, 7));
        let u = rng.dirichlet_symmetric(6, 1.0);
        let mut want = vec![0f32; 11];
        hmm.emit.vecmat(&u, &mut want);
        let mut got = vec![0f32; 11];
        HmmBackend::emit_vecmat(&hmm, &u, &mut got);
        assert_eq!(want, got);
        let mut want_t = vec![0f32; 6];
        hmm.trans.vecmat(&u, &mut want_t);
        let mut got_t = vec![0f32; 6];
        HmmBackend::trans_vecmat(&hmm, &u, &mut got_t);
        assert_eq!(want_t, got_t);
    }

    #[test]
    fn default_forward_step_uniform_resets_on_impossible_tokens() {
        let mut rng = Rng::seeded(15);
        let mut hmm = Hmm::random(5, 9, 0.5, 0.5, &mut rng);
        for h in 0..5 {
            hmm.emit.set(h, 3, 0.0);
        }
        let alpha = rng.dirichlet_symmetric(5, 1.0);
        let mut next = vec![0f32; 5];
        let scale = HmmBackend::forward_step(&hmm, &alpha, 3, &mut next);
        assert_eq!(scale, 0.0);
        for &n in &next {
            assert!((n - 0.2).abs() < 1e-6, "expected uniform reset, got {n}");
        }
    }

    /// A wrapper that deliberately keeps every default implementation,
    /// standing in for a third-party backend that predates the panel
    /// methods: the defaults must reproduce the per-beam ops exactly.
    struct DefaultsOnly(Hmm);

    impl HmmBackend for DefaultsOnly {
        fn hidden(&self) -> usize {
            HmmBackend::hidden(&self.0)
        }
        fn vocab(&self) -> usize {
            HmmBackend::vocab(&self.0)
        }
        fn init(&self) -> &[f32] {
            HmmBackend::init(&self.0)
        }
        fn trans_matvec(&self, v: &[f32], out: &mut [f32]) {
            self.0.trans_matvec(v, out);
        }
        fn trans_vecmat(&self, v: &[f32], out: &mut [f32]) {
            self.0.trans_vecmat(v, out);
        }
        fn emit_vecmat(&self, u: &[f32], out: &mut [f32]) {
            self.0.emit_vecmat(u, out);
        }
        fn emit_at(&self, h: usize, tok: usize) -> f32 {
            self.0.emit_at(h, tok)
        }
        fn emit_col(&self, tok: usize) -> Vec<(u32, f32)> {
            self.0.emit_col(tok)
        }
        fn nnz(&self) -> (usize, usize) {
            HmmBackend::nnz(&self.0)
        }
    }

    #[test]
    fn panel_methods_bit_identical_to_per_beam_ops() {
        // Both the overridden (dense Hmm → Mat::vecmat_panel) and the
        // default (looped) panel paths against B per-beam calls, and
        // against each other — the trait stays object-safe, so this
        // also exercises the methods through `&dyn HmmBackend`.
        let mut rng = Rng::seeded(16);
        let hmm = Hmm::random(9, 21, 0.3, 0.2, &mut rng);
        let wrapped = DefaultsOnly(hmm.clone());
        for b in [1usize, 3, 8, 17] {
            let u: Vec<f32> = (0..b * 9)
                .map(|_| if rng.below(4) == 0 { 0.0 } else { rng.f32() })
                .collect();
            for (model, label) in [(&hmm as &dyn HmmBackend, "override"), (&wrapped, "default")] {
                let mut fused = vec![0f32; b * 21];
                model.emit_panel(&u, b, &mut fused);
                let mut fused_t = vec![0f32; b * 9];
                model.trans_panel(&u, b, &mut fused_t);
                for bi in 0..b {
                    let mut want = vec![0f32; 21];
                    model.emit_vecmat(&u[bi * 9..(bi + 1) * 9], &mut want);
                    for c in 0..21 {
                        assert_eq!(
                            fused[bi * 21 + c].to_bits(),
                            want[c].to_bits(),
                            "{label} emit b={b} bi={bi} c={c}"
                        );
                    }
                    let mut want_t = vec![0f32; 9];
                    model.trans_vecmat(&u[bi * 9..(bi + 1) * 9], &mut want_t);
                    for h in 0..9 {
                        assert_eq!(
                            fused_t[bi * 9 + h].to_bits(),
                            want_t[h].to_bits(),
                            "{label} trans b={b} bi={bi} h={h}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn forward_step_panel_bit_identical_including_uniform_reset() {
        // A panel mixing live beams with one whose token has zero mass:
        // the fused step must uniform-reset that lane (scale 0.0)
        // without touching the others, matching B scalar forward_steps
        // to the bit — through both the override and the default path.
        let mut rng = Rng::seeded(17);
        let mut hmm = Hmm::random(7, 15, 0.4, 0.3, &mut rng);
        for h in 0..7 {
            hmm.emit.set(h, 5, 0.0); // token 5 is impossible
        }
        let wrapped = DefaultsOnly(hmm.clone());
        for (model, label) in [(&hmm as &dyn HmmBackend, "override"), (&wrapped, "default")] {
            let b = 4usize;
            let alphas: Vec<f32> = (0..b * 7).map(|_| rng.f32()).collect();
            let toks = [2usize, 5, 9, 5];
            let mut next = vec![0f32; b * 7];
            let mut scales = vec![0f64; b];
            model.forward_step_panel(&alphas, &toks, &mut next, &mut scales);
            for bi in 0..b {
                let mut want = vec![0f32; 7];
                let s = model.forward_step(&alphas[bi * 7..(bi + 1) * 7], toks[bi], &mut want);
                assert_eq!(scales[bi].to_bits(), s.to_bits(), "{label} bi={bi} scale");
                for h in 0..7 {
                    assert_eq!(
                        next[bi * 7 + h].to_bits(),
                        want[h].to_bits(),
                        "{label} bi={bi} h={h}"
                    );
                }
            }
            assert_eq!(scales[1], 0.0, "{label}: impossible token must report scale 0");
        }
    }

    #[test]
    fn dense_emit_col_collects_the_column() {
        let mut rng = Rng::seeded(13);
        let mut hmm = Hmm::random(4, 6, 0.5, 0.5, &mut rng);
        hmm.emit.set(2, 3, 0.0);
        let col = HmmBackend::emit_col(&hmm, 3);
        assert!(col.iter().all(|&(h, _)| h != 2), "zero entry must be dropped");
        for &(h, e) in &col {
            assert_eq!(e, hmm.emit.at(h as usize, 3));
        }
        // Sorted by h, no duplicates.
        assert!(col.windows(2).all(|w| w[0].0 < w[1].0));
    }
}
