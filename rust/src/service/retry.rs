//! `RetryBudget`: budget-capped retries that cannot amplify a brown-out.
//!
//! A naive retry policy ("every failure gets one retry") doubles the
//! offered load exactly when the fleet is least able to take it: a
//! replica brown-out makes every call fail, every failure retries, and
//! the retry wave keeps the replica brown. The classic fix (Finagle's
//! retry budget) makes retries a *fraction of successful traffic*
//! instead of a fraction of failures:
//!
//! - every initial call **deposits** `ratio` tokens (default 0.1) into
//!   a bucket capped at `cap` (default 10.0);
//! - every retry **withdraws** 1.0 token; an empty bucket means the
//!   failure is returned as-is (`Metrics::retry_exhausted`).
//!
//! In steady state at ratio 0.1 the fleet retries at most ~10% of its
//! traffic, no matter how hard the backend fails. Only `Err(Failed)`
//! is retried — `Overloaded` and `DeadlineExceeded` are load signals
//! where a retry is exactly the wrong medicine, and `Closed` is
//! permanent.
//!
//! In the fleet stack this layer sits *outside*
//! [`super::balance::Balance`], so a retry re-runs replica selection
//! and lands on a different (hopefully healthy) replica.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::Metrics;

use super::{Layer, Readiness, Service, ServiceError};

/// Default fraction of initial traffic that may be retried.
const DEFAULT_RATIO: f64 = 0.1;

/// Default token-bucket cap (burst of retries after a quiet period).
const DEFAULT_CAP: f64 = 10.0;

/// Default retries per request.
const DEFAULT_MAX_RETRIES: u32 = 1;

/// Budget-capped retry middleware; see the [module docs](self).
///
/// Requests must be `Clone` so a failed attempt can be re-sent.
///
/// ```
/// use std::sync::Arc;
/// use normq::coordinator::metrics::Metrics;
/// use normq::coordinator::ServeRequest;
/// use normq::service::{Echo, RetryBudget, Service};
///
/// let metrics = Arc::new(Metrics::new());
/// let svc = RetryBudget::new(Echo::instant(), Arc::clone(&metrics));
/// let resp = svc.call(ServeRequest::new(vec!["hello".into()])).unwrap();
/// assert_eq!(resp.text, "hello");
/// // A healthy backend never spends the budget.
/// assert_eq!(metrics.retries.load(std::sync::atomic::Ordering::Relaxed), 0);
/// ```
pub struct RetryBudget<S> {
    inner: S,
    ratio: f64,
    cap: f64,
    max_retries: u32,
    tokens: Mutex<f64>,
    metrics: Arc<Metrics>,
}

impl<S> RetryBudget<S> {
    /// Wrap `inner` with a full budget (ratio 0.1, cap 10, 1 retry).
    pub fn new(inner: S, metrics: Arc<Metrics>) -> Self {
        RetryBudget {
            inner,
            ratio: DEFAULT_RATIO,
            cap: DEFAULT_CAP,
            max_retries: DEFAULT_MAX_RETRIES,
            tokens: Mutex::new(DEFAULT_CAP),
            metrics,
        }
    }

    /// Tokens deposited per initial call — the steady-state fraction
    /// of traffic that may be retried (clamped to ≥ 0).
    pub fn with_ratio(mut self, ratio: f64) -> Self {
        self.ratio = ratio.max(0.0);
        self
    }

    /// Token-bucket cap: the largest retry burst after a quiet period.
    /// The bucket is refilled to the new cap.
    pub fn with_cap(mut self, cap: f64) -> Self {
        self.cap = cap.max(0.0);
        *self.tokens.lock().unwrap() = self.cap;
        self
    }

    /// Maximum retries per request (0 disables retrying).
    pub fn with_max_retries(mut self, max_retries: u32) -> Self {
        self.max_retries = max_retries;
        self
    }

    /// Current token balance (for tests and introspection).
    pub fn balance(&self) -> f64 {
        *self.tokens.lock().unwrap()
    }

    /// Try to withdraw one token; false means the budget is spent.
    fn withdraw(&self) -> bool {
        let mut tokens = self.tokens.lock().unwrap();
        if *tokens >= 1.0 {
            *tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

impl<Req, S> Service<Req> for RetryBudget<S>
where
    Req: Clone,
    S: Service<Req>,
{
    type Response = S::Response;

    fn poll_ready(&self) -> Readiness {
        self.inner.poll_ready()
    }

    fn call(&self, req: Req) -> Result<Self::Response, ServiceError> {
        {
            let mut tokens = self.tokens.lock().unwrap();
            *tokens = (*tokens + self.ratio).min(self.cap);
        }
        let mut out = self.inner.call(req.clone());
        let mut attempts = 0;
        while attempts < self.max_retries {
            match out {
                Err(ServiceError::Failed(_)) => {
                    if !self.withdraw() {
                        self.metrics.retry_exhausted.fetch_add(1, Ordering::Relaxed);
                        break;
                    }
                    self.metrics.retries.fetch_add(1, Ordering::Relaxed);
                    attempts += 1;
                    out = self.inner.call(req.clone());
                }
                _ => break,
            }
        }
        out
    }
}

/// Builds [`RetryBudget`] middlewares; see
/// [`super::stack::Stack::retry_budget`].
#[derive(Clone, Debug)]
pub struct RetryBudgetLayer {
    ratio: f64,
    max_retries: u32,
    metrics: Arc<Metrics>,
}

impl RetryBudgetLayer {
    /// A layer producing budgets with the given deposit `ratio` and
    /// retry cap per request.
    pub fn new(ratio: f64, max_retries: u32, metrics: Arc<Metrics>) -> Self {
        RetryBudgetLayer { ratio, max_retries, metrics }
    }
}

impl<S> Layer<S> for RetryBudgetLayer {
    type Service = RetryBudget<S>;
    fn layer(&self, inner: S) -> Self::Service {
        RetryBudget::new(inner, Arc::clone(&self.metrics))
            .with_ratio(self.ratio)
            .with_max_retries(self.max_retries)
    }
}

#[cfg(test)]
mod tests {
    use super::super::breaker::{FaultInjector, FaultPoint};
    use super::super::testutil::{MockSvc, TestReq};
    use super::*;

    #[test]
    fn successful_calls_never_retry() {
        let metrics = Arc::new(Metrics::new());
        let svc = RetryBudget::new(MockSvc::instant(), Arc::clone(&metrics));
        for _ in 0..5 {
            assert!(svc.call(TestReq::default()).is_ok());
        }
        assert_eq!(metrics.retries.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.retry_exhausted.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn failed_calls_retry_until_the_budget_is_spent() {
        let metrics = Arc::new(Metrics::new());
        let fault = FaultInjector::new();
        // ratio 0 → no deposits; cap 2 → exactly two retries ever.
        let svc = RetryBudget::new(
            FaultPoint::new(MockSvc::instant(), fault.clone()),
            Arc::clone(&metrics),
        )
        .with_ratio(0.0)
        .with_cap(2.0);
        fault.set_failing(true);
        for _ in 0..3 {
            assert!(matches!(svc.call(TestReq::default()), Err(ServiceError::Failed(_))));
        }
        assert_eq!(metrics.retries.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.retry_exhausted.load(Ordering::Relaxed), 1);
        assert_eq!(svc.balance(), 0.0);
    }

    #[test]
    fn deposits_replenish_the_budget() {
        let metrics = Arc::new(Metrics::new());
        let fault = FaultInjector::new();
        let svc = RetryBudget::new(
            FaultPoint::new(MockSvc::instant(), fault.clone()),
            Arc::clone(&metrics),
        )
        .with_ratio(0.5)
        .with_cap(1.0);
        // Drain the bucket with one failing call (deposit 0.5 caps at
        // 1.0, the retry withdraws it).
        fault.set_failing(true);
        let _ = svc.call(TestReq::default());
        assert_eq!(svc.balance(), 0.0);
        // Two healthy calls deposit 1.0 back…
        fault.set_failing(false);
        for _ in 0..2 {
            assert!(svc.call(TestReq::default()).is_ok());
        }
        // …so the next failure can afford its retry again.
        fault.set_failing(true);
        let _ = svc.call(TestReq::default());
        assert_eq!(metrics.retries.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn overload_errors_are_not_retried() {
        let metrics = Arc::new(Metrics::new());
        let mut inner = MockSvc::instant();
        inner.fail_call = Some(0);
        let svc = RetryBudget::new(inner, Arc::clone(&metrics));
        assert_eq!(svc.call(TestReq::default()), Err(ServiceError::Overloaded));
        assert_eq!(metrics.retries.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn max_retries_bounds_attempts_even_with_budget() {
        let metrics = Arc::new(Metrics::new());
        let fault = FaultInjector::new();
        let svc = RetryBudget::new(
            FaultPoint::new(MockSvc::instant(), fault.clone()),
            Arc::clone(&metrics),
        )
        .with_max_retries(3);
        fault.set_failing(true);
        let _ = svc.call(TestReq::default());
        // All three permitted retries ran (budget 10 covers them).
        assert_eq!(metrics.retries.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.retry_exhausted.load(Ordering::Relaxed), 0);
    }
}
