//! Latency profiling of the neuro-symbolic pipeline — the Fig 1
//! reproduction. Phases:
//!
//! - `neural.lm_forward` — the LM's next-token distribution (the paper's
//!   GPT2 MatMuls)
//! - `symbolic.table_build` — HMM×DFA backward table (HMM backward pass)
//! - `symbolic.matmul` — decode-step HMM MatMuls (u@emit, forward step)
//! - `symbolic.memcpy` — belief/buffer copies and beam state movement
//!   (the paper's memory-copy/data-transfer category)
//! - `coordinator.beam` — candidate sort / top-k
//!
//! Besides wall time we account *bytes moved* and *FLOPs* per phase, so
//! the arithmetic-intensity claim behind Fig 1 (symbolic part is
//! bandwidth-bound: ~1 flop/byte vs the neural part's reuse) is
//! measurable even though a CPU has no explicit host↔device memcpy.

use crate::data::vocab::EOS;
use crate::dfa::Dfa;
use crate::generate::{BuildOptions, ConstraintTable, DecodeConfig};
use crate::hmm::HmmBackend;
use crate::lm::LanguageModel;
use crate::util::timer::PhaseTimers;

/// Byte/flop accounting per phase.
#[derive(Clone, Debug, Default)]
#[allow(missing_docs)] // field names say it all
pub struct OpAccounting {
    pub neural_flops: f64,
    pub symbolic_flops: f64,
    pub symbolic_bytes: f64,
    pub neural_bytes: f64,
}

/// Instrumented variant of the **per-beam** decode loop (kept
/// structurally in sync with `generate::decode_with_table_perbeam`,
/// the scalar oracle; the uninstrumented paths stay clean for the
/// serving hot loop). The serving path itself now runs the batched
/// SoA engine (`generate::engine`), which is property-tested
/// bit-identical to this per-beam reference — so the phase split
/// measured here (table build vs MatMul vs memcpy vs beam sort)
/// remains representative of the fused path's work, while the
/// per-phase timers stay simple. Like the real decoder it reads
/// weights only through the [`HmmBackend`].
pub fn decode_profiled(
    lm: &dyn LanguageModel,
    model: &dyn HmmBackend,
    dfa: &Dfa,
    cfg: &DecodeConfig,
    timers: &PhaseTimers,
    acct: &mut OpAccounting,
) -> crate::generate::Generation {
    let vocab = model.vocab();
    let h_n = model.hidden();
    let table = timers.time("symbolic.table_build", || {
        ConstraintTable::build_with(model, dfa, cfg.max_tokens, &BuildOptions::default())
            .expect("unbounded build cannot expire")
    });
    acct.symbolic_flops +=
        (cfg.max_tokens * dfa.n_states() * h_n * h_n * 2) as f64;
    acct.symbolic_bytes += (cfg.max_tokens * dfa.n_states() * h_n * 8) as f64;

    struct B {
        tokens: Vec<usize>,
        score: f64,
        dfa_state: u32,
        alpha: Vec<f32>,
    }
    let mut beams = vec![B {
        tokens: Vec::new(),
        score: 0.0,
        dfa_state: dfa.start(),
        alpha: model.init().to_vec(),
    }];
    let mut done: Vec<(Vec<usize>, f64, u32)> = Vec::new();
    let mut lp = vec![0f32; vocab];
    let mut w = vec![0f32; vocab];
    let mut u = vec![0f32; h_n];

    for t in 0..cfg.max_tokens {
        let remaining = cfg.max_tokens - t;
        let mut candidates: Vec<(usize, usize, f64)> = Vec::new();
        for (bi, beam) in beams.iter().enumerate() {
            timers.time("neural.lm_forward", || {
                lm.next_log_probs(&beam.tokens, &mut lp)
            });
            acct.neural_flops += (vocab * 8) as f64; // n-gram scan estimate
            acct.neural_bytes += (vocab * 4) as f64;

            let d_def = dfa.default_next(beam.dfa_state);
            timers.time("symbolic.memcpy", || {
                let c_def = table.c(remaining - 1, d_def);
                for h in 0..h_n {
                    u[h] = beam.alpha[h] * c_def[h];
                }
            });
            acct.symbolic_bytes += (h_n * 12) as f64;
            timers.time("symbolic.matmul", || {
                model.emit_vecmat(&u, &mut w);
            });
            acct.symbolic_flops += (h_n * vocab * 2) as f64;
            acct.symbolic_bytes += (h_n * vocab * 4) as f64; // streams emit once

            timers.time("symbolic.matmul", || {
                for &(tok, next_d) in dfa.exceptions(beam.dfa_state) {
                    let c_exc = table.c(remaining - 1, next_d);
                    let mut accum = 0f64;
                    for h in 0..h_n {
                        accum += beam.alpha[h] as f64
                            * model.emit_at(h, tok as usize) as f64
                            * c_exc[h] as f64;
                    }
                    w[tok as usize] = accum as f32;
                }
            });
            let eos_next = dfa.next(beam.dfa_state, EOS);
            if dfa.is_accepting(eos_next) {
                let mut accum = 0f64;
                for h in 0..h_n {
                    accum += beam.alpha[h] as f64 * model.emit_at(h, EOS) as f64;
                }
                w[EOS] = accum as f32;
            } else {
                w[EOS] = 0.0;
            }
            let z: f64 = w.iter().map(|&x| x as f64).sum();
            if z <= 0.0 {
                continue;
            }
            let log_z = z.ln();
            for (x, (&lpx, &wx)) in lp.iter().zip(w.iter()).enumerate() {
                if wx > 0.0 {
                    let s =
                        beam.score + lpx as f64 + cfg.lambda as f64 * ((wx as f64).ln() - log_z);
                    if s.is_nan() {
                        continue;
                    }
                    candidates.push((bi, x, s));
                }
            }
        }
        if candidates.is_empty() {
            break;
        }
        timers.time("coordinator.beam", || {
            candidates.sort_by(|a, b| b.2.total_cmp(&a.2));
            candidates.truncate(cfg.beam);
        });
        let mut next = Vec::with_capacity(cfg.beam);
        for (bi, tok, score) in candidates {
            let parent = &beams[bi];
            let mut tokens = timers.time("symbolic.memcpy", || parent.tokens.clone());
            acct.symbolic_bytes += (tokens.len() * 8) as f64;
            tokens.push(tok);
            let dfa_state = dfa.next(parent.dfa_state, tok);
            if tok == EOS {
                done.push((tokens, score, dfa_state));
                continue;
            }
            let mut alpha_next = vec![0f32; h_n];
            timers.time("symbolic.matmul", || {
                model.forward_step(&parent.alpha, tok, &mut alpha_next);
            });
            acct.symbolic_flops += (h_n * h_n * 2) as f64;
            acct.symbolic_bytes += (h_n * h_n * 4) as f64;
            next.push(B { tokens, score, dfa_state, alpha: alpha_next });
        }
        beams = next;
        if beams.is_empty() {
            break;
        }
    }
    let best_done = done.into_iter().max_by(|a, b| a.1.total_cmp(&b.1));
    let (mut tokens, score) = match best_done {
        Some((t, s, _)) => (t, s),
        None => beams
            .into_iter()
            .max_by(|a, b| a.score.total_cmp(&b.score))
            .map(|b| (b.tokens, b.score))
            .unwrap_or((vec![EOS], f64::NEG_INFINITY)),
    };
    if tokens.last() == Some(&EOS) {
        tokens.pop();
    }
    let satisfied = dfa.accepts(&tokens);
    crate::generate::Generation { tokens, score, satisfied, timed_out: false }
}

/// One profiling run: decode `n_requests` items, return (phase report,
/// accounting).
pub fn profile_run(
    lm: &dyn LanguageModel,
    model: &dyn HmmBackend,
    corpus: &crate::data::Corpus,
    items: &[crate::data::EvalItem],
    cfg: &DecodeConfig,
) -> (PhaseTimers, OpAccounting) {
    let timers = PhaseTimers::new();
    let mut acct = OpAccounting::default();
    for item in items {
        let keywords: Vec<Vec<usize>> = item
            .concepts
            .iter()
            .map(|c| vec![corpus.vocab.id(c)])
            .collect();
        let dfa = Dfa::from_keywords(&keywords, corpus.vocab.len());
        let _ = decode_profiled(lm, model, &dfa, cfg, &timers, &mut acct);
    }
    (timers, acct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;
    use crate::hmm::em::em_step;
    use crate::lm::NgramLm;
    use crate::util::rng::Rng;

    #[test]
    fn profiled_decode_matches_plain_decode() {
        let corpus = Corpus::small(700);
        let data = corpus.sample_token_corpus(300, 31);
        let lm = NgramLm::train(&data, corpus.vocab.len());
        let mut rng = Rng::seeded(32);
        let mut hmm = crate::hmm::Hmm::random(8, corpus.vocab.len(), 0.5, 0.5, &mut rng);
        for _ in 0..4 {
            hmm = em_step(&hmm, &data, 4, 1e-9).0;
        }
        let kw = corpus.vocab.id(&corpus.lexicon.nouns[0]);
        let dfa = Dfa::from_keywords(&[vec![kw]], corpus.vocab.len());
        let cfg = DecodeConfig { beam: 4, max_tokens: 12, ..Default::default() };
        let plain = crate::generate::decode(&lm, &hmm, &dfa, &cfg);
        let timers = PhaseTimers::new();
        let mut acct = OpAccounting::default();
        let prof = decode_profiled(&lm, &hmm, &dfa, &cfg, &timers, &mut acct);
        assert_eq!(plain.tokens, prof.tokens, "instrumented decode diverged");
        assert_eq!(plain.satisfied, prof.satisfied);
        // All phases recorded.
        let phases: Vec<String> = timers.report().into_iter().map(|r| r.0).collect();
        for expected in ["neural.lm_forward", "symbolic.matmul", "symbolic.memcpy", "coordinator.beam", "symbolic.table_build"] {
            assert!(phases.iter().any(|p| p == expected), "missing {expected}");
        }
        assert!(acct.symbolic_flops > 0.0 && acct.symbolic_bytes > 0.0);
    }

    #[test]
    fn symbolic_intensity_is_lower_than_neural_reuse() {
        // The Fig 1 premise: symbolic ops have low arithmetic intensity.
        let corpus = Corpus::small(701);
        let data = corpus.sample_token_corpus(200, 33);
        let lm = NgramLm::train(&data, corpus.vocab.len());
        let mut rng = Rng::seeded(34);
        let hmm = crate::hmm::Hmm::random(16, corpus.vocab.len(), 0.3, 0.1, &mut rng);
        let items = corpus.eval_set(4, 1, 35);
        let cfg = DecodeConfig { beam: 4, max_tokens: 10, ..Default::default() };
        let (_timers, acct) = profile_run(&lm, &hmm, &corpus, &items, &cfg);
        let intensity = acct.symbolic_flops / acct.symbolic_bytes.max(1.0);
        assert!(intensity < 4.0, "symbolic intensity {intensity} not memory-bound");
    }
}
