//! Table II — layer-wise integer quantization, FP32 → INT8. Weights are
//! integer-quantized per tensor and activations are quantize-dequantized
//! around the decoder's MatMuls (`act_bits`). Expected shape: success
//! rate collapses below ~12 bits — the motivating failure of traditional
//! NN quantization on probabilistic models.

use crate::eval::evaluate;
use crate::generate::DecodeConfig;
use crate::quant::Method;
use crate::tables::{score_cells, scores_json, ExperimentContext, TableResult, SCORE_HEADER};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::log_info;

/// Run this experiment and produce its table/figure data.
pub fn run(args: &Args) -> Result<TableResult, String> {
    let ctx = ExperimentContext::build(args)?;
    let bits = args.usize_list("bits", &[24, 16, 14, 12, 11, 10, 9, 8])?;

    let mut rows = Vec::new();
    let mut json_rows = Vec::new();

    // FP32 baseline first.
    let (fp32, _) = evaluate(&ctx.lm, &ctx.hmm, &ctx.corpus, &ctx.items, &ctx.decode, ctx.threads);
    rows.push(score_cells("FP32", &fp32));
    json_rows.push(Json::obj(vec![
        ("method", Json::str("FP32")),
        ("scores", scores_json(&fp32)),
    ]));

    for &b in &bits {
        let m = Method::Integer { bits: b as u32 };
        log_info!("table2: {}", m.label());
        // Score through the serving-shaped backend (same decode path
        // the server runs); for Integer this is the dense qdq model.
        let hmm = m.backend(&ctx.hmm);
        let cfg = DecodeConfig { act_bits: Some(b as u32), ..ctx.decode.clone() };
        let (scores, _) =
            evaluate(&ctx.lm, hmm.as_ref(), &ctx.corpus, &ctx.items, &cfg, ctx.threads);
        rows.push(score_cells(&m.label(), &scores));
        json_rows.push(Json::obj(vec![
            ("method", Json::str(m.label())),
            ("bits", Json::num(b as f64)),
            ("scores", scores_json(&scores)),
        ]));
    }
    Ok(TableResult {
        id: "table2".into(),
        title: "layer-wise integer quantization (paper Table II)".into(),
        header: SCORE_HEADER.iter().map(|s| s.to_string()).collect(),
        rows,
        json: Json::arr(json_rows),
    })
}
