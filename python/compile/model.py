"""Layer-2 JAX compute graphs.

- A small decoder-only transformer LM (the neural part of the
  neuro-symbolic system; the GPT2-large stand-in per DESIGN.md §1).
- The HMM forward log-likelihood graph, built on the Layer-1 Pallas
  forward-step kernel so the kernel lowers into the same HLO module.

Both are lowered once by aot.py; Python never runs at serving time.
"""

import jax
import jax.numpy as jnp

from .kernels import hmm_step


# ---------------------------------------------------------------- LM ---

def init_lm_params(rng, vocab, d_model=64, n_layers=2, n_heads=4, d_ff=128, max_len=32):
    """Initialize transformer parameters (pytree of jnp arrays)."""
    keys = jax.random.split(rng, 4 + 8 * n_layers)
    k = iter(keys)

    def dense(key, fan_in, fan_out):
        return jax.random.normal(key, (fan_in, fan_out)) * (fan_in ** -0.5)

    params = {
        "embed": jax.random.normal(next(k), (vocab, d_model)) * 0.02,
        "pos": jax.random.normal(next(k), (max_len, d_model)) * 0.02,
        "out_ln_scale": jnp.ones((d_model,)),
        "out_ln_bias": jnp.zeros((d_model,)),
        "blocks": [],
        "meta": {"n_heads": n_heads, "max_len": max_len},
    }
    for _ in range(n_layers):
        params["blocks"].append({
            "ln1_scale": jnp.ones((d_model,)),
            "ln1_bias": jnp.zeros((d_model,)),
            "wq": dense(next(k), d_model, d_model),
            "wk": dense(next(k), d_model, d_model),
            "wv": dense(next(k), d_model, d_model),
            "wo": dense(next(k), d_model, d_model),
            "ln2_scale": jnp.ones((d_model,)),
            "ln2_bias": jnp.zeros((d_model,)),
            "w1": dense(next(k), d_model, d_ff),
            "w2": dense(next(k), d_ff, d_model),
        })
    return params


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _block(x, p, n_heads, mask):
    t, d = x.shape
    dh = d // n_heads
    h = _layer_norm(x, p["ln1_scale"], p["ln1_bias"])
    q = (h @ p["wq"]).reshape(t, n_heads, dh)
    k = (h @ p["wk"]).reshape(t, n_heads, dh)
    v = (h @ p["wv"]).reshape(t, n_heads, dh)
    att = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(dh)
    att = jnp.where(mask[None, :, :], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("hqk,khd->qhd", att, v).reshape(t, d)
    x = x + o @ p["wo"]
    h2 = _layer_norm(x, p["ln2_scale"], p["ln2_bias"])
    x = x + jax.nn.gelu(h2 @ p["w1"]) @ p["w2"]
    return x


def lm_forward(params, tokens):
    """All-position logits. tokens: [T] int32 -> [T, V] raw logits."""
    t = tokens.shape[0]
    n_heads = params["meta"]["n_heads"]
    x = params["embed"][tokens] + params["pos"][:t]
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    for p in params["blocks"]:
        x = _block(x, p, n_heads, causal)
    x = _layer_norm(x, params["out_ln_scale"], params["out_ln_bias"])
    return x @ params["embed"].T  # tied embedding


def lm_next_log_probs(params, tokens, length):
    """Log P(next token | tokens[:length]). tokens is a [T_max] padded
    buffer; `length` counts the real prefix (0 = empty prefix → the model
    conditions on BOS position only). Returns [V] log-probs."""
    logits = lm_forward(params, tokens)
    # Position length-1 predicts token at `length`; empty prefix uses a
    # BOS convention: tokens[0] is EOS-pad, so position 0 works for both.
    idx = jnp.maximum(length - 1, 0)
    row = jax.lax.dynamic_index_in_dim(logits, idx, axis=0, keepdims=False)
    return jax.nn.log_softmax(row)


# --------------------------------------------------------------- HMM ---

def hmm_forward_ll(tokens, length, init, trans, emit):
    """Masked scaled-forward log-likelihood using the Pallas step kernel.

    Same contract as kernels.ref.hmm_log_likelihood (the oracle).
    """

    def step(carry, t):
        alpha, ll = carry
        tok = tokens[t]
        emit_col = emit[:, tok][None, :]
        nxt, scale = hmm_step.forward_step(alpha, emit_col, trans)
        active = t < length
        ll = ll + jnp.where(active, jnp.log(jnp.maximum(scale[0], 1e-37)), 0.0)
        alpha = jnp.where(active, nxt, alpha)
        return (alpha, ll), None

    alpha0 = init[None, :]
    (_, ll), _ = jax.lax.scan(step, (alpha0, jnp.float32(0.0)), jnp.arange(tokens.shape[0]))
    return (ll.reshape(1),)


# ------------------------------------------------- flat weight order ---

def flatten_params(params):
    """Deterministic (name, array) list for the AOT weights file; the
    Rust runtime feeds these back as execute() arguments in this order."""
    out = [
        ("embed", params["embed"]),
        ("pos", params["pos"]),
        ("out_ln_scale", params["out_ln_scale"]),
        ("out_ln_bias", params["out_ln_bias"]),
    ]
    for i, b in enumerate(params["blocks"]):
        for key in ["ln1_scale", "ln1_bias", "wq", "wk", "wv", "wo",
                    "ln2_scale", "ln2_bias", "w1", "w2"]:
            out.append((f"block{i}.{key}", b[key]))
    return out


def unflatten_params(flat, n_layers, meta):
    """Inverse of flatten_params given the same ordering."""
    it = iter(flat)
    params = {
        "embed": next(it),
        "pos": next(it),
        "out_ln_scale": next(it),
        "out_ln_bias": next(it),
        "blocks": [],
        "meta": meta,
    }
    for _ in range(n_layers):
        params["blocks"].append({
            k: next(it)
            for k in ["ln1_scale", "ln1_bias", "wq", "wk", "wv", "wo",
                      "ln2_scale", "ln2_bias", "w1", "w2"]
        })
    return params
