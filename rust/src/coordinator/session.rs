//! Multi-turn session state: leases, pinned snapshots, idempotent
//! resume keys.
//!
//! A *session* lets a client decode one long constrained generation
//! across many requests: turn k suspends the beam search after its
//! token budget ([`crate::generate::engine::RequestState`] snapshots
//! into a [`SessionSnapshot`]), and turn k+1 resumes from the pinned
//! snapshot plus an `Arc` to the group's constraint table — instead of
//! re-decoding the whole prefix from scratch. The [`SessionTable`]
//! here owns that pinned state and enforces the protocol discipline
//! around it (modeled on lease/outbox dispatcher designs):
//!
//! - **Leases with heartbeat expiry.** Every session holds a [`Lease`]
//!   renewed by each turn. A silent client's lease runs out and the
//!   session is reaped — by the dispatcher's periodic
//!   [`SessionTable::reap`] when idle, or mid-decode through the
//!   lease's [`CancelProbe`] face, which the worker registers on the
//!   decode lane so an expired session frees its lane at the next
//!   step boundary. Either way the pinned bytes are released (the
//!   `session_bytes` gauge returns to zero).
//! - **Idempotent resume keys.** Each turn carries a client-chosen
//!   `resume_key`. A retried turn (same turn number, same key) replays
//!   the buffered previous [`Response`] instead of decoding twice —
//!   the at-most-once answer for an at-least-once client.
//! - **A pinned-byte budget.** Snapshots are charged against
//!   `--session-budget-mb`; past it, the least-recently-touched *idle*
//!   session is evicted (its client must start over — degraded, never
//!   wrong). Constraint tables are shared `Arc`s accounted by the
//!   table cache, so a session pins at most one snapshot's worth of
//!   beam state here.
//!
//! The lifecycle of one entry:
//!
//! ```text
//!          begin_turn(turn 1)                 begin_turn(turn k+1)
//!   (none) ───────────────────► in-flight ◄─────────────────────── idle
//!                                 │   ▲                              ▲
//!             complete_turn:      │   └── Replay / Reject leave ─────┤
//!               Continue ─────────┼──────────────────────────────────┘
//!               Done ─────────────┼────► idle tombstone (replay only)
//!               Rollback ─────────┼────► idle (state restored)
//!               Destroy ──────────┴────► (none)
//!   idle ── lease expiry / budget eviction ──► (none)
//! ```

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::dfa::Dfa;
use crate::generate::{CancelProbe, ConstraintTable, SessionSnapshot};

use super::metrics::Metrics;
use super::Response;

/// The session fields a [`super::ServeRequest`] may carry: which
/// session this turn belongs to, the client-chosen idempotency key for
/// the turn, the 1-based turn number, and this turn's token budget.
#[derive(Clone, Debug)]
pub struct SessionEnvelope {
    /// Client-chosen session identifier.
    pub session_id: String,
    /// Idempotency key for this turn: retrying a turn with the same
    /// key replays the buffered response instead of re-decoding.
    pub resume_key: String,
    /// 1-based turn number; must be exactly `turns_done + 1` (or
    /// `turns_done` with the same key, for a replay).
    pub turn: u32,
    /// Tokens this turn may emit before suspending (min 1).
    pub turn_tokens: usize,
}

/// A session's heartbeat lease. Renewed on every turn touch; once
/// `ttl` passes without one, the session is reaped. The lease doubles
/// as a [`CancelProbe`] on the session's decode lane, so expiry fires
/// mid-decode at the next step boundary rather than waiting for the
/// turn to finish on a client that is already gone.
#[derive(Debug)]
pub struct Lease {
    expires: Mutex<Instant>,
}

impl Lease {
    /// A fresh lease expiring `ttl` from now.
    pub fn new(ttl: Duration) -> Lease {
        Lease { expires: Mutex::new(Instant::now() + ttl) }
    }

    /// Heartbeat: push expiry to `ttl` from now.
    pub fn renew(&self, ttl: Duration) {
        *self.expires.lock().unwrap() = Instant::now() + ttl;
    }

    /// Whether the lease has run out.
    pub fn expired(&self) -> bool {
        Instant::now() >= *self.expires.lock().unwrap()
    }
}

impl CancelProbe for Lease {
    fn cancelled(&self) -> bool {
        self.expired()
    }
}

/// What a resumed turn decodes from: the suspended beam state and the
/// constraint table it was decoding against (shared with the table
/// cache — resuming never rebuilds).
#[derive(Clone)]
pub struct ResumeState {
    /// The suspended beam state (turn k's endpoint).
    pub snapshot: SessionSnapshot,
    /// The group's DFA + constraint table.
    pub state: Arc<(Dfa, ConstraintTable)>,
}

impl std::fmt::Debug for ResumeState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResumeState")
            .field("snapshot", &self.snapshot)
            .field("state", &"<dfa+table>")
            .finish()
    }
}

/// One pinned session.
struct SessionEntry {
    /// The suspended beam state; `None` while a turn is in flight
    /// (the worker holds it) or after the session completed.
    snapshot: Option<SessionSnapshot>,
    /// The constraint table the session decodes against.
    state: Option<Arc<(Dfa, ConstraintTable)>>,
    lease: Arc<Lease>,
    /// Turns completed so far (the last `Continue`/`Done`'s turn).
    turns_done: u32,
    /// The resume key of the last completed turn, for replay matching.
    last_key: String,
    /// The last completed turn's response, buffered for replay.
    last_response: Option<Response>,
    /// A turn is currently decoding; the entry cannot be resumed,
    /// replayed, evicted or reaped until it completes.
    in_flight: bool,
    /// Bytes charged against the session budget (the snapshot's).
    bytes: usize,
    /// Last client touch, for LRU-of-idle eviction.
    touched: Instant,
    /// The generation ran to completion; only replay remains.
    done: bool,
}

/// How [`SessionTable::begin_turn`] admits a turn.
pub enum TurnAdmission {
    /// Turn 1 of a new session (or a clean retry of a failed turn 1):
    /// decode from scratch under this lease.
    Fresh(Arc<Lease>),
    /// Turn k+1: resume the pinned snapshot against the pinned table.
    Resume {
        /// The suspended state to decode from.
        resume: ResumeState,
        /// The session's (renewed) lease.
        lease: Arc<Lease>,
    },
    /// Duplicate resume key: answer with the buffered response, no
    /// decode.
    Replay(Response),
    /// Protocol violation or dead session; answer failed with the
    /// reason.
    Reject(&'static str),
}

/// How a turn ended; [`SessionTable::complete_turn`] folds it back
/// into the entry.
pub enum TurnOutcome {
    /// The turn suspended at its token budget: re-pin the new snapshot
    /// and buffer the response for replay.
    Continue {
        /// The suspended beam state after this turn.
        snapshot: SessionSnapshot,
        /// The table the session decodes against (re-pinned).
        state: Arc<(Dfa, ConstraintTable)>,
        /// The turn's response, buffered for idempotent replay.
        response: Response,
    },
    /// The generation finished (EOS / budget / beams extinct): keep a
    /// zero-byte tombstone so the final turn stays replayable until
    /// the lease runs out.
    Done {
        /// The final turn's response, buffered for replay.
        response: Response,
    },
    /// The turn failed before producing a new snapshot (build failure,
    /// queue-expired deadline): restore the previous state, if any, so
    /// the client can retry the same turn.
    Rollback {
        /// The pre-turn state to restore (`None` for a failed turn 1).
        resume: Option<ResumeState>,
    },
    /// The session is dead (client cancelled, or its lease expired
    /// mid-decode): drop everything.
    Destroy,
}

/// The pinned-session registry: one entry per live session, a byte
/// budget over their snapshots, and the lease/replay protocol around
/// them. Shared by the dispatcher (admission, reaping) and the decode
/// workers (completion), so every method takes `&self` under one
/// internal lock — all operations are map-and-counter work, never
/// decode.
pub struct SessionTable {
    inner: Mutex<HashMap<String, SessionEntry>>,
    budget: usize,
    ttl: Duration,
    metrics: Arc<Metrics>,
}

impl SessionTable {
    /// An empty table: `budget` bytes of pinned snapshots, `ttl` of
    /// silence before a session is reaped.
    pub fn new(budget: usize, ttl: Duration, metrics: Arc<Metrics>) -> SessionTable {
        SessionTable { inner: Mutex::new(HashMap::new()), budget, ttl, metrics }
    }

    /// The lease TTL turns are renewed to.
    pub fn ttl(&self) -> Duration {
        self.ttl
    }

    /// Admit one turn. Renews the lease (any turn is a heartbeat),
    /// enforces turn ordering and single-flight-per-session, and picks
    /// the decode mode: fresh, resume, replay, or reject.
    pub fn begin_turn(&self, env: &SessionEnvelope) -> TurnAdmission {
        let mut map = self.inner.lock().unwrap();
        // Reap this id first: an expired entry must never be resumed.
        if map
            .get(&env.session_id)
            .is_some_and(|e| e.lease.expired() && !e.in_flight)
        {
            map.remove(&env.session_id);
            self.metrics.sessions_expired.fetch_add(1, Ordering::Relaxed);
        }
        let admission = match map.get_mut(&env.session_id) {
            None => {
                if env.turn == 1 {
                    let lease = Arc::new(Lease::new(self.ttl));
                    map.insert(
                        env.session_id.clone(),
                        SessionEntry {
                            snapshot: None,
                            state: None,
                            lease: Arc::clone(&lease),
                            turns_done: 0,
                            last_key: String::new(),
                            last_response: None,
                            in_flight: true,
                            bytes: 0,
                            touched: Instant::now(),
                            done: false,
                        },
                    );
                    self.metrics.sessions_opened.fetch_add(1, Ordering::Relaxed);
                    TurnAdmission::Fresh(lease)
                } else {
                    TurnAdmission::Reject("unknown session (never opened, or lease expired)")
                }
            }
            Some(entry) => {
                entry.touched = Instant::now();
                entry.lease.renew(self.ttl);
                if entry.in_flight {
                    TurnAdmission::Reject("a turn is already in flight for this session")
                } else if env.turn == entry.turns_done && env.resume_key == entry.last_key {
                    match entry.last_response.clone() {
                        Some(resp) => {
                            self.metrics.session_replays.fetch_add(1, Ordering::Relaxed);
                            TurnAdmission::Replay(resp)
                        }
                        None => TurnAdmission::Reject("duplicate turn with no buffered response"),
                    }
                } else if env.turn != entry.turns_done + 1 {
                    TurnAdmission::Reject("turn out of order")
                } else if entry.done {
                    TurnAdmission::Reject("session already complete")
                } else if entry.turns_done == 0 {
                    // Turn 1 rolled back; the retry decodes fresh.
                    entry.in_flight = true;
                    TurnAdmission::Fresh(Arc::clone(&entry.lease))
                } else {
                    match (entry.snapshot.take(), entry.state.clone()) {
                        (Some(snapshot), Some(state)) => {
                            entry.in_flight = true;
                            entry.bytes = 0;
                            self.metrics.sessions_resumed.fetch_add(1, Ordering::Relaxed);
                            TurnAdmission::Resume {
                                resume: ResumeState { snapshot, state },
                                lease: Arc::clone(&entry.lease),
                            }
                        }
                        _ => TurnAdmission::Reject("session has no resumable state"),
                    }
                }
            }
        };
        self.publish(&map);
        admission
    }

    /// Fold a finished turn back into its entry, then enforce the
    /// pinned-byte budget (evicting LRU idle sessions past it). A
    /// completion for an entry that no longer exists is dropped
    /// silently — its session was already destroyed.
    pub fn complete_turn(&self, env: &SessionEnvelope, outcome: TurnOutcome) {
        let mut map = self.inner.lock().unwrap();
        enum After {
            Keep,
            Expired,
            Cancelled,
        }
        let after = match map.get_mut(&env.session_id) {
            None => After::Keep,
            Some(entry) => {
                entry.in_flight = false;
                entry.touched = Instant::now();
                match outcome {
                    TurnOutcome::Continue { snapshot, state, response } => {
                        if entry.lease.expired() {
                            // The client went silent while we decoded;
                            // do not re-pin bytes nobody will claim.
                            After::Expired
                        } else {
                            entry.bytes = snapshot.bytes();
                            entry.snapshot = Some(snapshot);
                            entry.state = Some(state);
                            entry.turns_done = env.turn;
                            entry.last_key = env.resume_key.clone();
                            entry.last_response = Some(response);
                            entry.lease.renew(self.ttl);
                            After::Keep
                        }
                    }
                    TurnOutcome::Done { response } => {
                        entry.snapshot = None;
                        entry.state = None;
                        entry.bytes = 0;
                        entry.turns_done = env.turn;
                        entry.last_key = env.resume_key.clone();
                        entry.last_response = Some(response);
                        entry.done = true;
                        entry.lease.renew(self.ttl);
                        After::Keep
                    }
                    TurnOutcome::Rollback { resume } => {
                        if let Some(r) = resume {
                            entry.bytes = r.snapshot.bytes();
                            entry.snapshot = Some(r.snapshot);
                            entry.state = Some(r.state);
                        }
                        if entry.lease.expired() {
                            After::Expired
                        } else {
                            After::Keep
                        }
                    }
                    TurnOutcome::Destroy => {
                        if entry.lease.expired() {
                            After::Expired
                        } else {
                            After::Cancelled
                        }
                    }
                }
            }
        };
        match after {
            After::Keep => {}
            After::Expired => {
                map.remove(&env.session_id);
                self.metrics.sessions_expired.fetch_add(1, Ordering::Relaxed);
            }
            After::Cancelled => {
                map.remove(&env.session_id);
                self.metrics.sessions_cancelled.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.evict_over_budget(&mut map);
        self.publish(&map);
    }

    /// Reap every idle session whose lease has expired. Called by the
    /// dispatcher once per batch window; in-flight turns are skipped —
    /// their lease doubles as the lane's cancel probe, so they destroy
    /// themselves through [`SessionTable::complete_turn`].
    pub fn reap(&self) {
        let mut map = self.inner.lock().unwrap();
        let dead: Vec<String> = map
            .iter()
            .filter(|(_, e)| e.lease.expired() && !e.in_flight)
            .map(|(k, _)| k.clone())
            .collect();
        if dead.is_empty() {
            return;
        }
        for k in &dead {
            map.remove(k);
        }
        self.metrics
            .sessions_expired
            .fetch_add(dead.len() as u64, Ordering::Relaxed);
        self.publish(&map);
    }

    /// Evict least-recently-touched idle sessions until pinned bytes
    /// fit the budget. In-flight entries are skipped (their bytes are
    /// zero anyway — the worker holds the snapshot); so are zero-byte
    /// tombstones, which cost nothing.
    fn evict_over_budget(&self, map: &mut HashMap<String, SessionEntry>) {
        loop {
            let total: usize = map.values().map(|e| e.bytes).sum();
            if total <= self.budget {
                return;
            }
            let victim = map
                .iter()
                .filter(|(_, e)| !e.in_flight && e.bytes > 0)
                .min_by_key(|(_, e)| e.touched)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    map.remove(&k);
                    self.metrics.sessions_evicted.fetch_add(1, Ordering::Relaxed);
                }
                None => return,
            }
        }
    }

    /// Refresh the `sessions_live` / `session_bytes` gauges.
    fn publish(&self, map: &HashMap<String, SessionEntry>) {
        let bytes: usize = map.values().map(|e| e.bytes).sum();
        self.metrics
            .session_bytes
            .store(bytes as u64, Ordering::Relaxed);
        self.metrics
            .sessions_live
            .store(map.len() as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn envelope(sid: &str, key: &str, turn: u32) -> SessionEnvelope {
        SessionEnvelope {
            session_id: sid.into(),
            resume_key: key.into(),
            turn,
            turn_tokens: 4,
        }
    }

    fn response(id: u64) -> Response {
        Response {
            id,
            text: format!("turn-{id}"),
            tokens: Vec::new(),
            score: 0.0,
            satisfied: false,
            timed_out: false,
            failed: false,
            latency: Duration::ZERO,
            queue_wait: Duration::ZERO,
            tier: 32,
            degraded: false,
            session_id: None,
            turn: 0,
            session_done: false,
            replayed: false,
            fail_reason: None,
        }
    }

    fn table(budget: usize, ttl_ms: u64) -> (SessionTable, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        (
            SessionTable::new(budget, Duration::from_millis(ttl_ms), Arc::clone(&metrics)),
            metrics,
        )
    }

    #[test]
    fn lease_expires_and_renews() {
        let lease = Lease::new(Duration::from_millis(20));
        assert!(!lease.expired());
        assert!(!lease.cancelled());
        std::thread::sleep(Duration::from_millis(30));
        assert!(lease.expired());
        assert!(lease.cancelled());
        lease.renew(Duration::from_secs(5));
        assert!(!lease.expired());
    }

    #[test]
    fn turn_protocol_rejects_out_of_order_and_unknown() {
        let (table, _m) = table(1 << 20, 5_000);
        // Turn 2 of a session nobody opened.
        assert!(matches!(
            table.begin_turn(&envelope("s1", "k2", 2)),
            TurnAdmission::Reject(_)
        ));
        // Turn 1 opens it.
        assert!(matches!(
            table.begin_turn(&envelope("s1", "k1", 1)),
            TurnAdmission::Fresh(_)
        ));
        // A second turn while the first is in flight is rejected.
        assert!(matches!(
            table.begin_turn(&envelope("s1", "k1b", 2)),
            TurnAdmission::Reject(_)
        ));
    }

    #[test]
    fn done_turn_replays_and_then_completes() {
        let (table, m) = table(1 << 20, 5_000);
        let env = envelope("s1", "k1", 1);
        assert!(matches!(table.begin_turn(&env), TurnAdmission::Fresh(_)));
        table.complete_turn(&env, TurnOutcome::Done { response: response(7) });
        // Same key replays the buffered response.
        match table.begin_turn(&env) {
            TurnAdmission::Replay(resp) => assert_eq!(resp.id, 7),
            _ => panic!("expected replay"),
        }
        assert_eq!(m.session_replays.load(Ordering::Relaxed), 1);
        // The next turn of a done session is rejected.
        assert!(matches!(
            table.begin_turn(&envelope("s1", "k2", 2)),
            TurnAdmission::Reject(_)
        ));
        // A done tombstone pins no bytes.
        assert_eq!(m.session_bytes.load(Ordering::Relaxed), 0);
        assert_eq!(m.sessions_live.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn failed_turn_one_retries_fresh() {
        let (table, m) = table(1 << 20, 5_000);
        let env = envelope("s1", "k1", 1);
        assert!(matches!(table.begin_turn(&env), TurnAdmission::Fresh(_)));
        table.complete_turn(&env, TurnOutcome::Rollback { resume: None });
        // The retry is admitted fresh, not rejected or resumed.
        assert!(matches!(table.begin_turn(&env), TurnAdmission::Fresh(_)));
        table.complete_turn(&env, TurnOutcome::Destroy);
        assert_eq!(m.sessions_cancelled.load(Ordering::Relaxed), 1);
        assert_eq!(m.sessions_live.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn reap_frees_expired_idle_sessions() {
        let (table, m) = table(1 << 20, 10);
        let env = envelope("s1", "k1", 1);
        assert!(matches!(table.begin_turn(&env), TurnAdmission::Fresh(_)));
        table.complete_turn(&env, TurnOutcome::Done { response: response(1) });
        std::thread::sleep(Duration::from_millis(25));
        table.reap();
        assert_eq!(m.sessions_expired.load(Ordering::Relaxed), 1);
        assert_eq!(m.sessions_live.load(Ordering::Relaxed), 0);
        assert_eq!(m.session_bytes.load(Ordering::Relaxed), 0);
        // And the session is gone for the client too.
        assert!(matches!(
            table.begin_turn(&envelope("s1", "k2", 2)),
            TurnAdmission::Reject(_)
        ));
    }

    #[test]
    fn destroy_mid_flight_with_expired_lease_counts_expired() {
        let (table, m) = table(1 << 20, 10);
        let env = envelope("s1", "k1", 1);
        assert!(matches!(table.begin_turn(&env), TurnAdmission::Fresh(_)));
        // Lease runs out while the turn decodes; reap skips in-flight.
        std::thread::sleep(Duration::from_millis(25));
        table.reap();
        assert_eq!(m.sessions_expired.load(Ordering::Relaxed), 0);
        // The worker notices (the lease is its cancel probe) and
        // destroys the session.
        table.complete_turn(&env, TurnOutcome::Destroy);
        assert_eq!(m.sessions_expired.load(Ordering::Relaxed), 1);
        assert_eq!(m.sessions_cancelled.load(Ordering::Relaxed), 0);
        assert_eq!(m.sessions_live.load(Ordering::Relaxed), 0);
    }
}
