//! A Norm-Q-compressed HMM stored as sparse quantized levels — the
//! serving-side model representation.
//!
//! [`QuantizedHmm`] keeps `trans` and `emit` as [`SparseQMat`]s (CSR
//! over non-zero b-bit levels, per-row scale `1/Σ levels` — Norm-Q's
//! row normalization folded into dequantization) and implements the
//! full [`HmmBackend`] surface, so both hot consumers run directly
//! over the levels: the constraint-table engine in
//! [`crate::generate::product`] (O(nnz) per transition step) and the
//! beam loop in [`crate::generate::decode_with_table`] (O(nnz) per
//! acceptance product and forward step). A server configured with a
//! quantized backend never materializes dense FP32 matrices anywhere
//! on the request path.
//!
//! [`QuantizedHmm::to_hmm`] exists for tests and offline analysis:
//! the dense dequantized model is the reference the equivalence
//! proptests (and `tests/decode_equivalence.rs`) compare against.

use crate::hmm::{Hmm, HmmBackend};
use crate::quant::normq;
use crate::quant::packed::SparseQMat;

/// A sparse quantized HMM (see the [module docs](self)).
#[derive(Clone, Debug)]
pub struct QuantizedHmm {
    /// γ: initial distribution, Norm-Q'd but kept dense (length H — two
    /// orders of magnitude smaller than either matrix).
    pub init: Vec<f32>,
    /// α: transition levels, H×H CSR.
    pub trans: SparseQMat,
    /// β: emission levels, H×V CSR.
    pub emit: SparseQMat,
    /// Bits per stored level.
    pub bits: u32,
}

impl QuantizedHmm {
    /// Quantize a dense HMM at `bits` with Norm-Q semantics: b-bit
    /// fixed-point levels, per-row normalization by level sum (the ε
    /// mass on all-zero rows dequantizes to uniform).
    pub fn from_hmm(hmm: &Hmm, bits: u32) -> QuantizedHmm {
        let mut init = hmm.init.clone();
        normq::normq_vec(&mut init, bits, normq::DEFAULT_EPS);
        QuantizedHmm {
            init,
            trans: SparseQMat::from_mat(&hmm.trans, bits),
            emit: SparseQMat::from_mat(&hmm.emit, bits),
            bits,
        }
    }

    /// Materialize the dense dequantized model (decode path / tests).
    pub fn to_hmm(&self) -> Hmm {
        Hmm {
            init: self.init.clone(),
            trans: self.trans.to_mat(),
            emit: self.emit.to_mat(),
        }
    }

    /// Fraction of stored-out (zero-level) entries across both
    /// matrices — the sparsity the table engine exploits.
    pub fn sparsity(&self) -> f64 {
        let total = self.trans.rows * self.trans.cols + self.emit.rows * self.emit.cols;
        let nnz = self.trans.nnz() + self.emit.nnz();
        1.0 - nnz as f64 / total.max(1) as f64
    }

    /// Resident bytes of this representation (CSR arrays + init) —
    /// what a server holding the quantized model actually keeps in
    /// memory, vs [`Hmm::fp32_bytes`] for the dense model.
    pub fn model_bytes(&self) -> usize {
        self.init.len() * 4 + self.trans.resident_bytes() + self.emit.resident_bytes()
    }

    /// Synthesize a random sparse quantized model directly in CSR form
    /// — `nnz_per_row` non-zero levels per row of both matrices — with
    /// no dense intermediate. This is how the decode benches reach
    /// H=16k/64k: a 64k×64k FP32 transition matrix alone is ~17 GB,
    /// while the CSR levels for the same shape at 32 nnz/row are a few
    /// dozen MB. Uses [`SparseQMat::from_parts`], so all structural
    /// invariants are checked.
    pub fn random_sparse(
        hidden: usize,
        vocab: usize,
        nnz_per_row: usize,
        bits: u32,
        rng: &mut crate::util::rng::Rng,
    ) -> QuantizedHmm {
        let max_level = ((1u64 << bits) - 1) as u16;
        let mut build = |rows: usize, cols: usize| -> SparseQMat {
            let nnz = nnz_per_row.min(cols);
            let mut row_ptr = Vec::with_capacity(rows + 1);
            let mut col_idx = Vec::with_capacity(rows * nnz);
            let mut levels = Vec::with_capacity(rows * nnz);
            row_ptr.push(0u32);
            let mut picked = std::collections::BTreeSet::new();
            for _ in 0..rows {
                picked.clear();
                while picked.len() < nnz {
                    picked.insert(rng.below(cols as u64) as u32);
                }
                for &c in picked.iter() {
                    col_idx.push(c);
                    levels.push(1 + rng.below(max_level as u64) as u16);
                }
                row_ptr.push(col_idx.len() as u32);
            }
            SparseQMat::from_parts(rows, cols, bits, row_ptr, col_idx, levels)
        };
        let trans = build(hidden, hidden);
        let emit = build(hidden, vocab);
        let mut init = rng.dirichlet_symmetric(hidden, 1.0);
        normq::normq_vec(&mut init, bits, normq::DEFAULT_EPS);
        QuantizedHmm {
            init,
            trans,
            emit,
            bits,
        }
    }
}

impl HmmBackend for QuantizedHmm {
    fn hidden(&self) -> usize {
        self.trans.rows
    }

    fn vocab(&self) -> usize {
        self.emit.cols
    }

    fn init(&self) -> &[f32] {
        &self.init
    }

    fn trans_matvec(&self, v: &[f32], out: &mut [f32]) {
        self.trans.matvec(v, out);
    }

    fn trans_vecmat(&self, v: &[f32], out: &mut [f32]) {
        self.trans.vecmat(v, out);
    }

    fn emit_vecmat(&self, u: &[f32], out: &mut [f32]) {
        self.emit.vecmat(u, out);
    }

    fn emit_at(&self, h: usize, tok: usize) -> f32 {
        self.emit.value(h, tok)
    }

    fn emit_col(&self, tok: usize) -> Vec<(u32, f32)> {
        (0..self.emit.rows)
            .filter_map(|h| {
                let e = self.emit.value(h, tok);
                (e != 0.0).then_some((h as u32, e))
            })
            .collect()
    }

    fn nnz(&self) -> (usize, usize) {
        (self.trans.nnz(), self.emit.nnz())
    }

    fn emit_panel(&self, u: &[f32], b: usize, out: &mut [f32]) {
        self.emit.vecmat_panel(u, b, out);
    }

    fn trans_panel(&self, v: &[f32], b: usize, out: &mut [f32]) {
        self.trans.vecmat_panel(v, b, out);
    }

    fn emit_panel_with(
        &self,
        u: &[f32],
        b: usize,
        out: &mut [f32],
        scratch: &mut crate::util::kernel::KernelScratch,
    ) {
        self.emit.vecmat_panel_with(u, b, out, scratch);
    }

    fn trans_panel_with(
        &self,
        v: &[f32],
        b: usize,
        out: &mut [f32],
        scratch: &mut crate::util::kernel::KernelScratch,
    ) {
        self.trans.vecmat_panel_with(v, b, out, scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_matches_sparse_views() {
        let mut rng = Rng::seeded(21);
        let hmm = Hmm::random(8, 40, 0.1, 0.05, &mut rng);
        let q = QuantizedHmm::from_hmm(&hmm, 8);
        let dense = q.to_hmm();
        for h in 0..8 {
            for t in 0..40 {
                assert!(
                    (q.emit.value(h, t) - dense.emit.at(h, t)).abs() < 1e-6,
                    "h={h} t={t}"
                );
            }
        }
        let v = rng.dirichlet_symmetric(8, 1.0);
        let mut want = vec![0f32; 8];
        dense.trans.matvec(&v, &mut want);
        let mut got = vec![0f32; 8];
        q.trans_matvec(&v, &mut got);
        for h in 0..8 {
            assert!((want[h] - got[h]).abs() < 1e-5, "h={h}");
        }
    }

    #[test]
    fn emit_col_matches_dense_column() {
        let mut rng = Rng::seeded(22);
        let hmm = Hmm::random(6, 20, 0.2, 0.1, &mut rng);
        let q = QuantizedHmm::from_hmm(&hmm, 4);
        let dense = q.to_hmm();
        for tok in 0..20 {
            let col = q.emit_col(tok);
            for &(h, e) in &col {
                assert!((e - dense.emit.at(h as usize, tok)).abs() < 1e-6);
            }
            // Every dense non-zero in the column must be present.
            let listed: Vec<u32> = col.iter().map(|&(h, _)| h).collect();
            for h in 0..6 {
                if dense.emit.at(h, tok) != 0.0 {
                    assert!(listed.contains(&(h as u32)), "tok={tok} h={h} missing");
                }
            }
        }
    }

    #[test]
    fn decode_ops_match_the_dense_dequantization() {
        let mut rng = Rng::seeded(25);
        let hmm = Hmm::random(7, 30, 0.2, 0.1, &mut rng);
        let q = QuantizedHmm::from_hmm(&hmm, 8);
        let dense = q.to_hmm();
        assert_eq!(HmmBackend::vocab(&q), 30);
        assert_eq!(HmmBackend::init(&q), &q.init[..]);
        for h in 0..7 {
            for tok in [0usize, 11, 29] {
                assert!(
                    (q.emit_at(h, tok) - dense.emit.at(h, tok)).abs() < 1e-6,
                    "h={h} tok={tok}"
                );
            }
        }
        let u = rng.dirichlet_symmetric(7, 1.0);
        let mut want = vec![0f32; 30];
        dense.emit.vecmat(&u, &mut want);
        let mut got = vec![0f32; 30];
        q.emit_vecmat(&u, &mut got);
        for c in 0..30 {
            assert!((want[c] - got[c]).abs() < 1e-5, "c={c}");
        }
        let mut want_t = vec![0f32; 7];
        dense.trans.vecmat(&u, &mut want_t);
        let mut got_t = vec![0f32; 7];
        q.trans_vecmat(&u, &mut got_t);
        for h in 0..7 {
            assert!((want_t[h] - got_t[h]).abs() < 1e-5, "h={h}");
        }
    }

    #[test]
    fn forward_step_matches_dense_backend() {
        let mut rng = Rng::seeded(26);
        let hmm = Hmm::random(6, 18, 0.3, 0.2, &mut rng);
        let q = QuantizedHmm::from_hmm(&hmm, 8);
        let dense = q.to_hmm();
        let alpha = rng.dirichlet_symmetric(6, 1.0);
        for tok in 0..18 {
            let mut next_q = vec![0f32; 6];
            let mut next_d = vec![0f32; 6];
            let s_q = q.forward_step(&alpha, tok, &mut next_q);
            let s_d = HmmBackend::forward_step(&dense, &alpha, tok, &mut next_d);
            assert!((s_q - s_d).abs() < 1e-6, "tok={tok} scale {s_q} vs {s_d}");
            for h in 0..6 {
                assert!(
                    (next_q[h] - next_d[h]).abs() < 1e-4,
                    "tok={tok} h={h} {} vs {}",
                    next_q[h],
                    next_d[h]
                );
            }
        }
    }

    #[test]
    fn panel_overrides_bit_identical_to_per_beam_ops() {
        let mut rng = Rng::seeded(27);
        let hmm = Hmm::random(11, 25, 0.3, 0.2, &mut rng);
        for bits in [3u32, 8, 12] {
            let q = QuantizedHmm::from_hmm(&hmm, bits);
            for b in [1usize, 3, 8, 17] {
                let u: Vec<f32> = (0..b * 11)
                    .map(|_| if rng.below(4) == 0 { 0.0 } else { rng.f32() })
                    .collect();
                let mut fused_e = vec![0f32; b * 25];
                q.emit_panel(&u, b, &mut fused_e);
                let mut fused_t = vec![0f32; b * 11];
                q.trans_panel(&u, b, &mut fused_t);
                for bi in 0..b {
                    let mut want = vec![0f32; 25];
                    q.emit_vecmat(&u[bi * 11..(bi + 1) * 11], &mut want);
                    for c in 0..25 {
                        assert_eq!(
                            fused_e[bi * 25 + c].to_bits(),
                            want[c].to_bits(),
                            "bits={bits} b={b} bi={bi} c={c}"
                        );
                    }
                    let mut want_t = vec![0f32; 11];
                    q.trans_vecmat(&u[bi * 11..(bi + 1) * 11], &mut want_t);
                    for h in 0..11 {
                        assert_eq!(
                            fused_t[bi * 11 + h].to_bits(),
                            want_t[h].to_bits(),
                            "bits={bits} b={b} bi={bi} h={h}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn random_sparse_is_valid_and_panel_consistent() {
        let mut rng = Rng::seeded(28);
        let q = QuantizedHmm::random_sparse(33, 47, 5, 8, &mut rng);
        assert_eq!(HmmBackend::hidden(&q), 33);
        assert_eq!(HmmBackend::vocab(&q), 47);
        assert_eq!(q.trans.nnz(), 33 * 5);
        assert_eq!(q.emit.nnz(), 33 * 5);
        // Dequantized rows are distributions (row scale = 1/Σ levels).
        assert!(q.to_hmm().is_valid(1e-3));
        // And the synthesized CSR runs the panel path bit-identically.
        let b = 4usize;
        let u: Vec<f32> = (0..b * 33).map(|_| rng.f32()).collect();
        let mut fused = vec![0f32; b * 47];
        q.emit_panel(&u, b, &mut fused);
        for bi in 0..b {
            let mut want = vec![0f32; 47];
            q.emit_vecmat(&u[bi * 33..(bi + 1) * 33], &mut want);
            for c in 0..47 {
                assert_eq!(fused[bi * 47 + c].to_bits(), want[c].to_bits());
            }
        }
    }

    #[test]
    fn quantized_model_is_far_smaller_than_fp32() {
        let mut rng = Rng::seeded(23);
        // Spiky rows ≈ trained HMM weights (paper Fig 2).
        let hmm = Hmm::random(64, 500, 0.02, 0.01, &mut rng);
        let q = QuantizedHmm::from_hmm(&hmm, 8);
        assert!(q.sparsity() > 0.5, "sparsity={}", q.sparsity());
        assert!(
            q.model_bytes() < hmm.fp32_bytes() / 2,
            "quantized {} vs fp32 {}",
            q.model_bytes(),
            hmm.fp32_bytes()
        );
    }

    #[test]
    fn validity_survives_quantization_via_uniform_fallback() {
        // Even at 2 bits (heavy auto-pruning) the dequantized model is
        // row-stochastic: surviving rows renormalize by level sum,
        // dead rows dequantize to uniform.
        let mut rng = Rng::seeded(24);
        let hmm = Hmm::random(12, 64, 0.05, 0.02, &mut rng);
        let q = QuantizedHmm::from_hmm(&hmm, 2);
        assert!(q.to_hmm().is_valid(1e-3));
    }
}
