//! Evaluation harness: runs the constrained-generation task over the
//! evaluation set and aggregates the paper's five columns — constraint
//! success rate, ROUGE(-L), BLEU4, CIDEr, SPICE* (proxy).

pub mod metrics;

use crate::data::{Corpus, EvalItem};
use crate::dfa::Dfa;
use crate::generate::{decode, DecodeConfig};
use crate::hmm::HmmBackend;
use crate::lm::LanguageModel;
use crate::util::threadpool::parallel_map;
use metrics::{bleu4, rouge_l_multi, spice_proxy, CiderScorer};

/// The five numbers every table in the paper reports (x100).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[allow(missing_docs)] // field names are the metric names
pub struct Scores {
    pub success_rate: f64,
    pub rouge: f64,
    pub bleu4: f64,
    pub cider: f64,
    pub spice: f64,
}

impl Scores {
    /// Format as the paper's "x100%" row.
    pub fn row(&self) -> String {
        format!(
            "{:5.1} {:5.1} {:5.1} {:5.1} {:5.1}",
            self.success_rate * 100.0,
            self.rouge * 100.0,
            self.bleu4 * 100.0,
            self.cider * 100.0,
            self.spice * 100.0
        )
    }

    /// Mean of the four quality scores (the paper's "scores drop by X%
    /// on average" aggregations).
    pub fn mean_quality(&self) -> f64 {
        (self.rouge + self.bleu4 + self.cider + self.spice) / 4.0
    }
}

/// One generated output with its item index.
#[derive(Clone, Debug, Default)]
pub struct EvalOutput {
    /// Index into the evaluation set.
    pub item: usize,
    /// The decoded sentence.
    pub text: String,
    /// Whether every concept was planted.
    pub satisfied: bool,
}

/// Run the full evaluation: decode every item, compute all metrics.
/// Decoding is parallel over items (each item is an independent request).
///
/// The model comes in as a [`HmmBackend`], so the offline sweeps
/// (Tables II/V/VI) run the *same* sparse quantized decode path the
/// server does — no dense materialization of a quantized model is
/// needed to score it (a `&Hmm` still coerces here for FP32 rows).
pub fn evaluate(
    lm: &dyn LanguageModel,
    hmm: &dyn HmmBackend,
    corpus: &Corpus,
    items: &[EvalItem],
    cfg: &DecodeConfig,
    threads: usize,
) -> (Scores, Vec<EvalOutput>) {
    let outputs: Vec<EvalOutput> = parallel_map(
        &items.iter().enumerate().collect::<Vec<_>>(),
        threads,
        |(i, item)| {
            let keywords: Vec<Vec<usize>> = item
                .concepts
                .iter()
                .map(|c| vec![corpus.vocab.id(c)])
                .collect();
            let dfa = Dfa::from_keywords(&keywords, corpus.vocab.len());
            let gen = decode(lm, hmm, &dfa, cfg);
            EvalOutput {
                item: *i,
                text: corpus.vocab.decode(&gen.tokens),
                satisfied: gen.satisfied,
            }
        },
    );
    (score_outputs(corpus, items, &outputs), outputs)
}

/// Aggregate metric computation given decoded outputs.
pub fn score_outputs(corpus: &Corpus, items: &[EvalItem], outputs: &[EvalOutput]) -> Scores {
    assert_eq!(items.len(), outputs.len());
    if items.is_empty() {
        return Scores::default();
    }
    let n = items.len() as f64;
    let success = outputs.iter().filter(|o| o.satisfied).count() as f64 / n;

    // Valid quality scores require non-garbled output; the paper marks
    // quality as "-" when success collapses to 0. We still compute the
    // numbers (callers decide presentation).
    let all_refs: Vec<Vec<String>> = items.iter().map(|i| i.references.clone()).collect();
    let cider_scorer = CiderScorer::fit(&all_refs);
    let is_content = |w: &str| corpus.lexicon.is_content(w);

    let mut rouge = 0f64;
    let mut cider = 0f64;
    let mut spice = 0f64;
    let mut bleu_items = Vec::with_capacity(items.len());
    for (item, out) in items.iter().zip(outputs.iter()) {
        rouge += rouge_l_multi(&out.text, &item.references);
        cider += cider_scorer.score(&out.text, &item.references);
        spice += spice_proxy(&out.text, &item.references, &is_content);
        bleu_items.push((out.text.clone(), item.references.clone()));
    }
    Scores {
        success_rate: success,
        rouge: rouge / n,
        bleu4: bleu4(&bleu_items),
        cider: cider / n,
        spice: spice / n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;
    use crate::hmm::em::em_step;
    use crate::lm::NgramLm;
    use crate::util::rng::Rng;

    fn pipeline() -> (Corpus, NgramLm, Hmm, Vec<EvalItem>) {
        let corpus = Corpus::small(500);
        let data = corpus.sample_token_corpus(400, 21);
        let lm = NgramLm::train(&data, corpus.vocab.len());
        let mut rng = Rng::seeded(22);
        let mut hmm = Hmm::random(10, corpus.vocab.len(), 0.5, 0.5, &mut rng);
        for _ in 0..5 {
            hmm = em_step(&hmm, &data, 4, 1e-9).0;
        }
        let items = corpus.eval_set(24, 2, 23);
        (corpus, lm, hmm, items)
    }

    #[test]
    fn full_pipeline_scores_reasonably() {
        let (corpus, lm, hmm, items) = pipeline();
        let cfg = DecodeConfig { beam: 6, max_tokens: 16, ..Default::default() };
        let (scores, outputs) = evaluate(&lm, &hmm, &corpus, &items, &cfg, 4);
        assert_eq!(outputs.len(), items.len());
        // A trained FP32 pipeline should satisfy most constraints.
        assert!(scores.success_rate > 0.8, "success={}", scores.success_rate);
        // Outputs share the template grammar — quality must be non-trivial.
        assert!(scores.rouge > 0.2, "rouge={}", scores.rouge);
        assert!(scores.spice > 0.1, "spice={}", scores.spice);
    }

    #[test]
    fn score_outputs_perfect_match() {
        let (corpus, _lm, _hmm, items) = pipeline();
        // Feed references back as outputs: success should be ~1, rouge 1.
        let outputs: Vec<EvalOutput> = items
            .iter()
            .enumerate()
            .map(|(i, item)| EvalOutput {
                item: i,
                text: item.references[0].clone(),
                satisfied: true,
            })
            .collect();
        let scores = score_outputs(&corpus, &items, &outputs);
        assert!((scores.success_rate - 1.0).abs() < 1e-9);
        assert!(scores.rouge > 0.99);
        assert!(scores.bleu4 > 0.9);
        assert!(scores.spice > 0.99);
    }

    #[test]
    fn garbled_outputs_score_near_zero() {
        let (corpus, _lm, _hmm, items) = pipeline();
        let outputs: Vec<EvalOutput> = items
            .iter()
            .enumerate()
            .map(|(i, _)| EvalOutput {
                item: i,
                text: "<unk> <unk> <unk>".to_string(),
                satisfied: false,
            })
            .collect();
        let scores = score_outputs(&corpus, &items, &outputs);
        assert_eq!(scores.success_rate, 0.0);
        assert!(scores.rouge < 0.05);
        assert!(scores.mean_quality() < 0.05);
    }

    #[test]
    fn row_formatting() {
        let s = Scores {
            success_rate: 1.0,
            rouge: 0.376,
            bleu4: 0.351,
            cider: 0.115,
            spice: 0.269,
        };
        let row = s.row();
        assert!(row.contains("100.0"));
        assert!(row.contains("37.6"));
    }
}
