//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! - ε in the Norm-Q row normalization (paper uses 1e-12; how sensitive?)
//! - the normalization step itself (Norm-Q vs raw fixed-point)
//! - λ, the symbolic-term weight in the decoder score (the paper's
//!   future-work "co-optimization" axis)
//! - beam width (paper uses 128 on GPT2-large; what does this scale need?)

use normq::data::{chunked, Corpus};
use normq::eval::evaluate;
use normq::generate::DecodeConfig;
use normq::hmm::forward::mean_log_likelihood;
use normq::hmm::Hmm;
use normq::lm::NgramLm;
use normq::qem::{train, QemConfig};
use normq::quant::normq as nq;
use normq::quant::Method;
use normq::util::rng::Rng;

fn main() {
    normq::util::logging::init_from_env();
    println!("== bench_ablation ==");
    let corpus = Corpus::new(21);
    let data = corpus.sample_token_corpus(3000, 22);
    let lm = NgramLm::train(&data, corpus.vocab.len());
    let test = corpus.sample_token_corpus(300, 23);
    let mut rng = Rng::seeded(24);
    let init = Hmm::random(64, corpus.vocab.len(), 0.3, 0.1, &mut rng);
    let cfg = QemConfig { method: None, epochs: 2, eval_test: false, ..Default::default() };
    let hmm = train(&init, &chunked(data, 10), &[], &cfg).model;
    let items = corpus.eval_set(60, 2, 25);
    let threads = normq::util::threadpool::default_threads();

    // --- ε sweep (test LLD of the 4-bit quantized model) ---
    println!("\nNorm-Q epsilon ablation (4-bit, test LLD; paper eps=1e-12):");
    for eps in [1e-6f64, 1e-9, 1e-12, 1e-15, 0.0] {
        let q = nq::normq_hmm(&hmm, 4, eps);
        let lld = mean_log_likelihood(&q, &test, threads);
        println!("  eps={eps:>8.0e}: test LLD {lld:8.3} valid={}", q.is_valid(1e-3));
    }

    // --- normalization on/off at matched bits (success + LLD) ---
    println!("\nnormalization ablation (success rate / test LLD):");
    let dcfg = DecodeConfig { beam: 6, max_tokens: 20, ..Default::default() };
    for bits in [8u32, 4, 3] {
        for (label, m) in [
            ("fixed  ", Method::Fixed { bits }),
            ("Norm-Q ", Method::NormQ { bits }),
        ] {
            let q = m.apply(&hmm);
            let lld = mean_log_likelihood(&q, &test, threads);
            let (s, _) = evaluate(&lm, &q, &corpus, &items, &dcfg, threads);
            println!(
                "  {bits}b {label}: success {:5.1}  LLD {lld:9.3}",
                s.success_rate * 100.0
            );
        }
    }

    // --- λ sweep (symbolic weight in the decoder) ---
    println!("\nlambda (symbolic weight) ablation, Norm-Q 8b:");
    let q8 = Method::NormQ { bits: 8 }.apply(&hmm);
    for lambda in [0.0f32, 0.25, 0.5, 1.0, 2.0, 4.0] {
        let cfg = DecodeConfig { beam: 6, max_tokens: 20, lambda, ..Default::default() };
        let (s, _) = evaluate(&lm, &q8, &corpus, &items, &cfg, threads);
        println!(
            "  lambda={lambda:>4}: success {:5.1} rouge {:4.1} bleu {:4.1}",
            s.success_rate * 100.0,
            s.rouge * 100.0,
            s.bleu4 * 100.0
        );
    }

    // --- beam sweep ---
    println!("\nbeam-width ablation, Norm-Q 8b:");
    for beam in [1usize, 2, 4, 8, 16] {
        let cfg = DecodeConfig { beam, max_tokens: 20, ..Default::default() };
        let t0 = std::time::Instant::now();
        let (s, _) = evaluate(&lm, &q8, &corpus, &items, &cfg, threads);
        println!(
            "  beam={beam:>2}: success {:5.1} rouge {:4.1} ({:.1}s)",
            s.success_rate * 100.0,
            s.rouge * 100.0,
            t0.elapsed().as_secs_f64()
        );
    }
}
