//! The bench-regression gate: diff the current bench-trajectory
//! artifact (`BENCH_tables.json` / `BENCH_decode.json` /
//! `BENCH_coordinator.json`) against a rolling window of previous runs
//! and flag slowdowns.
//!
//! Each artifact is `{bench, quick, scenarios: [..]}` where every
//! scenario object mixes *identity* fields (hidden, bits, alpha, …)
//! with *timing* fields (`*_ms`, plus the derived `speedup`). The gate
//! matches scenarios across runs by their identity fields — so adding,
//! removing or re-parameterizing scenarios never fails the gate, only
//! a matched scenario getting slower does — and reports a regression
//! when any timing field exceeds the **median of the window's**
//! baselines by more than the threshold (CI uses 25%). The median (of
//! up to N previous artifacts, CI keeps 3) makes the gate robust to a
//! single noisy CI run in either direction: one slow baseline cannot
//! *mask* a real regression and one fast baseline cannot *fake* one.
//! Fewer artifacts than N — including the old single-baseline mode —
//! degrade gracefully to the median of whatever is available; runs at
//! a different scale (`quick` flag mismatch) are dropped from the
//! window, and a prev artifact missing a scenario simply contributes
//! nothing to that scenario's baseline.
//!
//! Used by `src/bin/bench_gate.rs` in the CI bench-smoke job, which
//! downloads the previous successful runs' artifacts and fails the job
//! on any regression — the trajectory bites instead of accumulating.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Fields that carry measurements rather than scenario identity —
/// timings, derived ratios, and *measured model properties* (sparsity,
/// table size). Measured floats must stay out of the match key: a
/// last-ulp shift from an unrelated change would silently unmatch
/// every scenario and turn the gate into a no-op.
fn is_measured_field(key: &str) -> bool {
    key.ends_with("_ms") || key.ends_with("_kb") || key == "speedup" || key == "sparsity"
}

/// The identity of one scenario: its configured (non-measured) fields,
/// canonically serialized (object keys are sorted, so this is
/// deterministic).
fn scenario_key(scenario: &Json) -> Option<String> {
    match scenario {
        Json::Obj(map) => {
            let identity: BTreeMap<String, Json> = map
                .iter()
                .filter(|(k, _)| !is_measured_field(k))
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
            Some(Json::Obj(identity).to_string())
        }
        _ => None,
    }
}

/// One timing field of one matched scenario that got slower than the
/// threshold allows.
#[derive(Clone, Debug)]
pub struct Regression {
    /// Canonical identity of the scenario (its non-timing fields).
    pub scenario: String,
    /// The timing field that regressed (e.g. `sparse_ms`).
    pub field: String,
    /// Previous run's value, milliseconds.
    pub prev_ms: f64,
    /// Current run's value, milliseconds.
    pub cur_ms: f64,
}

impl Regression {
    /// Slowdown ratio (current / previous).
    pub fn ratio(&self) -> f64 {
        self.cur_ms / self.prev_ms.max(1e-12)
    }
}

/// What the gate found when diffing two artifacts.
#[derive(Clone, Debug, Default)]
pub struct GateReport {
    /// Matched scenarios compared field-by-field.
    pub compared: usize,
    /// Current scenarios with no counterpart in the previous run.
    pub unmatched: usize,
    /// Timing fields beyond the slowdown threshold.
    pub regressions: Vec<Regression>,
    /// Human-readable notes (scale mismatch, best improvement, …).
    pub notes: Vec<String>,
}

impl GateReport {
    /// True when no matched timing field regressed.
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// The default rolling-window depth: the median of the last 3
/// artifacts tolerates one noisy run in either direction.
pub const DEFAULT_WINDOW: usize = 3;

/// Median of a non-empty sample (mean of the middle pair when even).
fn median(values: &mut [f64]) -> f64 {
    values.sort_by(|a, b| a.total_cmp(b));
    let n = values.len();
    if n % 2 == 1 {
        values[n / 2]
    } else {
        (values[n / 2 - 1] + values[n / 2]) / 2.0
    }
}

/// Diff `cur` against the single baseline `prev` (a one-artifact
/// window); see [`gate_window`].
pub fn gate(prev: &Json, cur: &Json, threshold: f64) -> Result<GateReport, String> {
    gate_window(std::slice::from_ref(prev), cur, threshold)
}

/// Diff `cur` against a rolling window of previous artifacts, flagging
/// any matched timing field where `cur > median(window) · (1 +
/// threshold)`. A prev artifact naming a different bench is an error
/// (the caller mixed trajectories); one at a different scale (`quick`
/// mismatch) or without scenarios is dropped from the window with a
/// note. Missing/NaN fields are skipped, not errors: a malformed
/// *previous* artifact must not wedge the pipeline. An empty (or
/// fully-dropped) window compares nothing and passes.
pub fn gate_window(prevs: &[Json], cur: &Json, threshold: f64) -> Result<GateReport, String> {
    let mut report = GateReport::default();
    let cur_scenarios = cur
        .get("scenarios")
        .and_then(Json::as_arr)
        .ok_or("current artifact has no scenarios array")?;

    // Index each usable window member's scenarios by identity key.
    let mut window: Vec<BTreeMap<String, &Json>> = Vec::new();
    for (i, prev) in prevs.iter().enumerate() {
        if prev.get("bench") != cur.get("bench") {
            return Err(format!(
                "artifact mismatch: baseline {} is {:?}, current is {:?}",
                i + 1,
                prev.get("bench"),
                cur.get("bench")
            ));
        }
        if prev.get("quick").and_then(Json::as_bool) != cur.get("quick").and_then(Json::as_bool)
        {
            report.notes.push(format!(
                "baseline {}: quick-mode mismatch — scales are incomparable, dropped from \
                 the window",
                i + 1
            ));
            continue;
        }
        let Some(scenarios) = prev.get("scenarios").and_then(Json::as_arr) else {
            report.notes.push(format!(
                "baseline {}: no scenarios array — dropped from the window",
                i + 1
            ));
            continue;
        };
        let mut by_key = BTreeMap::new();
        for s in scenarios {
            if let Some(k) = scenario_key(s) {
                by_key.insert(k, s);
            }
        }
        window.push(by_key);
    }
    if window.is_empty() {
        report
            .notes
            .push("no usable baseline in the window — nothing to compare".into());
        report.unmatched = cur_scenarios.len();
        return Ok(report);
    }
    if window.len() > 1 {
        report
            .notes
            .push(format!("baseline: median of {} artifacts", window.len()));
    }

    let mut best_improvement: Option<(String, f64)> = None;
    for scenario in cur_scenarios {
        let key = match scenario_key(scenario) {
            Some(k) => k,
            None => continue,
        };
        let matched: Vec<&&Json> = window.iter().filter_map(|w| w.get(&key)).collect();
        if matched.is_empty() {
            report.unmatched += 1;
            continue;
        }
        report.compared += 1;
        let Json::Obj(fields) = scenario else { continue };
        for (field, value) in fields.iter().filter(|(k, _)| k.ends_with("_ms")) {
            let Some(cur_ms) = value.as_f64() else { continue };
            // A scenario present in a window member but missing this
            // field (or carrying junk) contributes nothing to the
            // baseline for it.
            let mut baselines: Vec<f64> = matched
                .iter()
                .filter_map(|p| p.get(field).and_then(Json::as_f64))
                .filter(|v| v.is_finite() && *v > 0.0)
                .collect();
            if !cur_ms.is_finite() || baselines.is_empty() {
                continue;
            }
            let prev_ms = median(&mut baselines);
            if cur_ms > prev_ms * (1.0 + threshold) {
                report.regressions.push(Regression {
                    scenario: key.clone(),
                    field: field.clone(),
                    prev_ms,
                    cur_ms,
                });
            } else if cur_ms < prev_ms {
                let gain = prev_ms / cur_ms.max(1e-12);
                let better = match &best_improvement {
                    Some((_, g)) => gain > *g,
                    None => true,
                };
                if better {
                    best_improvement = Some((format!("{key} {field}"), gain));
                }
            }
        }
    }
    if let Some((what, gain)) = best_improvement {
        report
            .notes
            .push(format!("best improvement: {what} {gain:.2}x faster"));
    }
    // The window has scenarios but none matched: the baseline is
    // incomparable (identity fields changed wholesale). Say so loudly —
    // a gate that silently compares nothing reads as green.
    let window_nonempty = window.iter().any(|w| !w.is_empty());
    if report.compared == 0 && !cur_scenarios.is_empty() && window_nonempty {
        report.notes.push(format!(
            "WARNING: 0 of {} scenario(s) matched the baseline — identity fields changed; \
             the gate checked nothing this run",
            cur_scenarios.len()
        ));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(quick: bool, scenarios: Vec<Json>) -> Json {
        Json::obj(vec![
            ("bench", Json::str("decode")),
            ("quick", Json::Bool(quick)),
            ("scenarios", Json::arr(scenarios)),
        ])
    }

    fn scenario(hidden: f64, bits: f64, dense_ms: f64, sparse_ms: f64) -> Json {
        Json::obj(vec![
            ("hidden", Json::num(hidden)),
            ("bits", Json::num(bits)),
            ("dense_ms", Json::num(dense_ms)),
            ("sparse_ms", Json::num(sparse_ms)),
            ("speedup", Json::num(dense_ms / sparse_ms)),
        ])
    }

    #[test]
    fn unchanged_runs_pass() {
        let a = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let report = gate(&a, &a, 0.25).unwrap();
        assert!(report.passed());
        assert_eq!(report.compared, 1);
        assert_eq!(report.unmatched, 0);
    }

    #[test]
    fn slowdown_beyond_threshold_is_a_regression() {
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let cur = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.6)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert!(!report.passed());
        assert_eq!(report.regressions.len(), 1);
        let r = &report.regressions[0];
        assert_eq!(r.field, "sparse_ms");
        assert!((r.ratio() - 1.3).abs() < 1e-9);
    }

    #[test]
    fn slowdown_within_threshold_passes() {
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let cur = artifact(true, vec![scenario(64.0, 8.0, 11.0, 2.4)]);
        assert!(gate(&prev, &cur, 0.25).unwrap().passed());
    }

    #[test]
    fn speedup_field_is_never_gated() {
        // speedup is derived from the ms fields; a *rising* speedup
        // (sparse got faster) must not read as a regression.
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 4.0)]);
        let cur = artifact(true, vec![scenario(64.0, 8.0, 10.0, 1.0)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert!(report.passed());
    }

    #[test]
    fn measured_fields_do_not_break_scenario_matching() {
        // sparsity/table_kb are measured, not configured: a last-ulp
        // drift must not unmatch the scenario (which would turn the
        // gate into a silent no-op), and the timing comparison must
        // still fire.
        let with_sparsity = |sparsity: f64, sparse_ms: f64| {
            Json::obj(vec![
                ("hidden", Json::num(64.0)),
                ("bits", Json::num(8.0)),
                ("sparsity", Json::num(sparsity)),
                ("table_kb", Json::num(112.0 + sparsity)),
                ("sparse_ms", Json::num(sparse_ms)),
            ])
        };
        let prev = artifact(true, vec![with_sparsity(0.9231, 2.0)]);
        let cur = artifact(true, vec![with_sparsity(0.9230, 2.6)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert_eq!(report.compared, 1, "sparsity drift must not unmatch");
        assert_eq!(report.regressions.len(), 1);
    }

    #[test]
    fn fully_unmatched_runs_warn_loudly() {
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let cur = artifact(true, vec![scenario(96.0, 3.0, 10.0, 2.0)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert_eq!(report.compared, 0);
        assert!(
            report.notes.iter().any(|n| n.contains("WARNING")),
            "a gate that compared nothing must say so: {:?}",
            report.notes
        );
    }

    #[test]
    fn reparameterized_scenarios_skip_instead_of_failing() {
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let cur = artifact(true, vec![scenario(96.0, 8.0, 99.0, 99.0)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert!(report.passed());
        assert_eq!(report.compared, 0);
        assert_eq!(report.unmatched, 1);
    }

    #[test]
    fn quick_mode_mismatch_skips_cleanly() {
        let prev = artifact(false, vec![scenario(64.0, 8.0, 1.0, 1.0)]);
        let cur = artifact(true, vec![scenario(64.0, 8.0, 99.0, 99.0)]);
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert!(report.passed());
        assert_eq!(report.compared, 0);
    }

    #[test]
    fn different_benches_refuse_to_compare() {
        let mut prev = artifact(true, vec![]);
        if let Json::Obj(m) = &mut prev {
            m.insert("bench".into(), Json::str("tables"));
        }
        let cur = artifact(true, vec![]);
        assert!(gate(&prev, &cur, 0.25).is_err());
    }

    #[test]
    fn one_noisy_slow_baseline_cannot_mask_a_regression() {
        // Two honest baselines at 2.0ms, one noisy at 9.0ms. Against
        // the *last run only* (the old gate), cur = 2.6 vs 9.0 would
        // pass; against the window median (2.0) it is a >25% slowdown.
        let prevs = vec![
            artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]),
            artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]),
            artifact(true, vec![scenario(64.0, 8.0, 10.0, 9.0)]),
        ];
        let cur = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.6)]);
        let report = gate_window(&prevs, &cur, 0.25).unwrap();
        assert_eq!(report.compared, 1);
        assert_eq!(report.regressions.len(), 1, "{:?}", report.regressions);
        assert_eq!(report.regressions[0].field, "sparse_ms");
        assert!((report.regressions[0].prev_ms - 2.0).abs() < 1e-9, "median baseline");
    }

    #[test]
    fn one_noisy_fast_baseline_cannot_fake_a_regression() {
        // One freak-fast run (0.1ms) among honest 2.0ms baselines: the
        // median keeps cur = 2.2 within threshold.
        let prevs = vec![
            artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]),
            artifact(true, vec![scenario(64.0, 8.0, 10.0, 0.1)]),
            artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]),
        ];
        let cur = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.2)]);
        assert!(gate_window(&prevs, &cur, 0.25).unwrap().passed());
    }

    #[test]
    fn window_shorter_than_n_degrades_to_available_artifacts() {
        // One artifact: identical to the old single-baseline gate.
        let prev = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]);
        let cur = artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.6)]);
        let one = gate_window(std::slice::from_ref(&prev), &cur, 0.25).unwrap();
        assert_eq!(one.regressions.len(), 1);
        // Two artifacts: even-count median is the mean of the pair —
        // (2.0 + 3.0)/2 = 2.5, so 2.6 passes at 25%.
        let prevs = vec![
            artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]),
            artifact(true, vec![scenario(64.0, 8.0, 10.0, 3.0)]),
        ];
        let two = gate_window(&prevs, &cur, 0.25).unwrap();
        assert!(two.passed(), "{:?}", two.regressions);
        // Empty window: compares nothing, passes.
        let none = gate_window(&[], &cur, 0.25).unwrap();
        assert!(none.passed());
        assert_eq!(none.compared, 0);
    }

    #[test]
    fn baselines_missing_a_scenario_contribute_nothing_to_it() {
        // The middle baseline predates the (96, 8) scenario entirely;
        // its absence must not unmatch the scenario or dilute the
        // median of the runs that do have it.
        let prevs = vec![
            artifact(
                true,
                vec![scenario(64.0, 8.0, 10.0, 2.0), scenario(96.0, 8.0, 20.0, 4.0)],
            ),
            artifact(true, vec![scenario(64.0, 8.0, 10.0, 2.0)]),
            artifact(
                true,
                vec![scenario(64.0, 8.0, 10.0, 2.0), scenario(96.0, 8.0, 20.0, 4.0)],
            ),
        ];
        let cur = artifact(
            true,
            vec![scenario(64.0, 8.0, 10.0, 2.0), scenario(96.0, 8.0, 20.0, 5.5)],
        );
        let report = gate_window(&prevs, &cur, 0.25).unwrap();
        assert_eq!(report.compared, 2);
        assert_eq!(report.regressions.len(), 1, "{:?}", report.regressions);
        assert!((report.regressions[0].prev_ms - 4.0).abs() < 1e-9);
        assert!((report.regressions[0].ratio() - 5.5 / 4.0).abs() < 1e-9);
    }

    #[test]
    fn incomparable_baselines_are_dropped_from_the_window() {
        // A quick-mode run in the window is dropped; the remaining
        // full-scale baselines still gate.
        let prevs = vec![
            artifact(false, vec![scenario(64.0, 8.0, 10.0, 2.0)]),
            artifact(true, vec![scenario(64.0, 8.0, 1.0, 0.2)]), // quick: dropped
            artifact(false, vec![scenario(64.0, 8.0, 10.0, 2.0)]),
        ];
        let cur = artifact(false, vec![scenario(64.0, 8.0, 10.0, 2.6)]);
        let report = gate_window(&prevs, &cur, 0.25).unwrap();
        assert_eq!(report.regressions.len(), 1);
        assert!((report.regressions[0].prev_ms - 2.0).abs() < 1e-9);
        assert!(report.notes.iter().any(|n| n.contains("dropped")), "{:?}", report.notes);
    }

    #[test]
    fn round_trips_through_serialization() {
        // The gate consumes artifacts exactly as the benches write
        // them: serialize, reparse, diff.
        let prev =
            artifact(true, vec![scenario(64.0, 3.0, 8.0, 1.5), scenario(64.0, 8.0, 9.0, 2.0)]);
        let cur =
            artifact(true, vec![scenario(64.0, 3.0, 8.1, 3.0), scenario(64.0, 8.0, 9.0, 2.0)]);
        let prev = Json::parse(&prev.to_string()).unwrap();
        let cur = Json::parse(&cur.to_string()).unwrap();
        let report = gate(&prev, &cur, 0.25).unwrap();
        assert_eq!(report.compared, 2);
        assert_eq!(report.regressions.len(), 1, "{:?}", report.regressions);
        assert_eq!(report.regressions[0].field, "sparse_ms");
    }
}
