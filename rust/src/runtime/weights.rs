//! Reader for `artifacts/lm_weights.bin`, the AOT transformer weights
//! passed as runtime arguments to the `lm_logits` executable (HLO text
//! elides large constants, so weights cannot live inside the module).
//!
//! Format (little-endian): u32 tensor_count, then per tensor —
//! u32 name_len, name bytes, u32 ndim, u32 dims[ndim], f32 data (C order).

use anyhow::{bail, Context, Result};
use std::path::Path;

/// One named tensor from the weights file.
#[derive(Clone, Debug)]
pub struct WeightTensor {
    /// Parameter name (flatten_params order key).
    pub name: String,
    /// Shape, outermost first.
    pub dims: Vec<usize>,
    /// Row-major (C order) values.
    pub data: Vec<f32>,
}

impl WeightTensor {
    /// Total element count (product of dims).
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Checked product of header dims: a malformed/adversarial header can
/// encode dims whose product wraps `usize` (silently in release builds),
/// turning the later bounds check into a pass and the data read into
/// garbage. Overflow must be a parse error, not UB-adjacent wrapping.
fn checked_elements(name: &str, dims: &[usize]) -> Result<usize> {
    dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d)).ok_or_else(|| {
        anyhow::anyhow!("tensor {name:?}: element count overflows (dims {dims:?})")
    })
}

/// Sanity cap on tensor rank: a huge `ndim` in a corrupt header would
/// otherwise drive a near-endless dims-read loop.
const MAX_RANK: usize = 16;

/// Parse the weights file (see the module docs for the format).
pub fn read_weights(path: &Path) -> Result<Vec<WeightTensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let mut pos = 0usize;
    let take_u32 = |pos: &mut usize| -> Result<u32> {
        if *pos + 4 > bytes.len() {
            bail!("truncated weights file at byte {}", *pos);
        }
        let v = u32::from_le_bytes(bytes[*pos..*pos + 4].try_into().unwrap());
        *pos += 4;
        Ok(v)
    };
    let count = take_u32(&mut pos)? as usize;
    // Capacity hint only — a corrupt count must not pre-allocate GBs.
    let mut out = Vec::with_capacity(count.min(1024));
    for _ in 0..count {
        let name_len = take_u32(&mut pos)? as usize;
        let name_end = pos
            .checked_add(name_len)
            .filter(|&e| e <= bytes.len())
            .ok_or_else(|| anyhow::anyhow!("truncated name at byte {pos}"))?;
        let name = String::from_utf8(bytes[pos..name_end].to_vec())
            .context("non-utf8 tensor name")?;
        pos = name_end;
        let ndim = take_u32(&mut pos)? as usize;
        if ndim > MAX_RANK {
            bail!("tensor {name:?}: implausible rank {ndim} (max {MAX_RANK})");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(take_u32(&mut pos)? as usize);
        }
        let n = checked_elements(&name, &dims)?;
        let data_end = n
            .checked_mul(4)
            .and_then(|b| pos.checked_add(b))
            .filter(|&e| e <= bytes.len())
            .ok_or_else(|| anyhow::anyhow!("truncated data for {name} ({n} elements)"))?;
        let mut data = Vec::with_capacity(n);
        for chunk in bytes[pos..data_end].chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        pos = data_end;
        out.push(WeightTensor { name, dims, data });
    }
    if pos != bytes.len() {
        bail!("trailing bytes in weights file ({} of {})", pos, bytes.len());
    }
    Ok(out)
}

/// Convert to xla literals in file order (scalar ranks handled).
pub fn to_literals(tensors: &[WeightTensor]) -> Result<Vec<xla::Literal>> {
    tensors
        .iter()
        .map(|t| {
            let lit = xla::Literal::vec1(&t.data);
            if t.dims.len() <= 1 {
                Ok(lit)
            } else {
                let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                Ok(lit.reshape(&dims)?)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_file(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // tensor "a": shape [2,3]
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"a").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        for i in 0..6 {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
        // tensor "bias": shape [4]
        f.write_all(&4u32.to_le_bytes()).unwrap();
        f.write_all(b"bias").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&4u32.to_le_bytes()).unwrap();
        for i in 0..4 {
            f.write_all(&(i as f32 * 0.5).to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn roundtrip_read() {
        let dir = std::env::temp_dir().join("normq_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        write_test_file(&path);
        let ts = read_weights(&path).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "a");
        assert_eq!(ts[0].dims, vec![2, 3]);
        assert_eq!(ts[0].data, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(ts[1].name, "bias");
        assert_eq!(ts[1].dims, vec![4]);
    }

    #[test]
    fn truncated_file_errors() {
        let dir = std::env::temp_dir().join("normq_weights_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [1u8, 0, 0]).unwrap();
        assert!(read_weights(&path).is_err());
    }

    /// A header whose dims product overflows `usize` must fail cleanly,
    /// not wrap (release mode) into a bogus small bounds check.
    #[test]
    fn overflowing_dims_error_cleanly() {
        let dir = std::env::temp_dir().join("normq_weights_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("overflow.bin");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap(); // 1 tensor
        f.write_all(&1u32.to_le_bytes()).unwrap(); // name_len 1
        f.write_all(b"x").unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap(); // ndim 3
        for _ in 0..3 {
            f.write_all(&u32::MAX.to_le_bytes()).unwrap(); // 2^96 elements
        }
        drop(f);
        let err = read_weights(&path).unwrap_err();
        assert!(err.to_string().contains("overflow"), "got: {err:#}");
    }

    /// A plausible-looking element count whose *byte* size still exceeds
    /// the file must be a truncation error, not a panic.
    #[test]
    fn oversized_data_claim_errors_cleanly() {
        let dir = std::env::temp_dir().join("normq_weights_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("oversize.bin");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"y").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap(); // ndim 1
        f.write_all(&1_000_000u32.to_le_bytes()).unwrap(); // 1M elements, no data
        drop(f);
        let err = read_weights(&path).unwrap_err();
        assert!(err.to_string().contains("truncated data"), "got: {err:#}");
    }

    /// Absurd ranks are rejected before the dims-read loop spins.
    #[test]
    fn implausible_rank_errors_cleanly() {
        let dir = std::env::temp_dir().join("normq_weights_test5");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rank.bin");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"z").unwrap();
        f.write_all(&u32::MAX.to_le_bytes()).unwrap(); // ndim = 4B
        drop(f);
        let err = read_weights(&path).unwrap_err();
        assert!(err.to_string().contains("rank"), "got: {err:#}");
    }
}
