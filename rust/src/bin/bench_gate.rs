//! bench_gate — the CI bench-regression gate.
//!
//! Usage: `bench_gate <previous.json> <current.json> [--threshold 0.25]`
//!
//! Diffs two bench-trajectory artifacts (`BENCH_tables.json` /
//! `BENCH_decode.json`) with `normq::util::benchgate`: scenarios are
//! matched by their identity fields and every `*_ms` timing field is
//! compared; any matched field slower than `previous · (1 + threshold)`
//! prints a regression line and exits 1 (failing the bench-smoke job).
//! Scenario-set changes, scale (`quick`) mismatches and unreadable
//! previous artifacts skip cleanly — only a real slowdown bites.

use normq::util::benchgate::gate;
use normq::util::json::Json;

fn run() -> Result<bool, String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut paths = Vec::new();
    let mut threshold = 0.25f64;
    let mut i = 0;
    while i < argv.len() {
        if argv[i] == "--threshold" {
            let v = argv
                .get(i + 1)
                .ok_or("--threshold expects a value (e.g. 0.25)")?;
            threshold = v
                .parse::<f64>()
                .map_err(|e| format!("--threshold {v:?}: {e}"))?;
            if !threshold.is_finite() || threshold <= 0.0 {
                return Err(format!("--threshold expects a positive ratio, got {v}"));
            }
            i += 2;
        } else {
            paths.push(argv[i].clone());
            i += 1;
        }
    }
    let [prev_path, cur_path] = paths.as_slice() else {
        return Err("usage: bench_gate <previous.json> <current.json> [--threshold 0.25]".into());
    };

    let cur_text = std::fs::read_to_string(cur_path)
        .map_err(|e| format!("reading current artifact {cur_path}: {e}"))?;
    let cur = Json::parse(&cur_text).map_err(|e| format!("parsing {cur_path}: {e}"))?;
    // A previous artifact that cannot be read or parsed is a skip, not
    // a failure: the first run of a new bench has no history, and a
    // corrupt upload must not wedge every future build.
    let prev = match std::fs::read_to_string(prev_path) {
        Ok(text) => match Json::parse(&text) {
            Ok(v) => v,
            Err(e) => {
                println!("[bench_gate] previous artifact unparseable ({e}) — skipping gate");
                return Ok(true);
            }
        },
        Err(e) => {
            println!("[bench_gate] no previous artifact ({e}) — skipping gate");
            return Ok(true);
        }
    };

    let report = gate(&prev, &cur, threshold)?;
    for note in &report.notes {
        println!("[bench_gate] {note}");
    }
    println!(
        "[bench_gate] {}: compared {} scenario(s), {} unmatched, threshold {:.0}%",
        cur_path,
        report.compared,
        report.unmatched,
        threshold * 100.0
    );
    for r in &report.regressions {
        eprintln!(
            "[bench_gate] REGRESSION {} {}: {:.2}ms -> {:.2}ms ({:.2}x, limit {:.2}x)",
            r.scenario,
            r.field,
            r.prev_ms,
            r.cur_ms,
            r.ratio(),
            1.0 + threshold
        );
    }
    Ok(report.passed())
}

fn main() {
    match run() {
        Ok(true) => println!("[bench_gate] OK"),
        Ok(false) => {
            eprintln!("[bench_gate] FAILED: bench regression(s) above threshold");
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("[bench_gate] error: {e}");
            std::process::exit(2);
        }
    }
}
