//! `Quota`: per-client token buckets with a shared overflow pool.
//!
//! Where [`super::rate::RateLimit`] paces the *aggregate* stream (and
//! blocks), `Quota` is policy: each client owns a token bucket sized by
//! [`QuotaConfig::rate`]/[`QuotaConfig::burst`], and a call from a
//! client whose bucket is empty first tries the shared overflow pool —
//! slack capacity any client may borrow while the system is idle —
//! then is denied with `Err(Overloaded)` without touching shared
//! resources. Denials are counted in `Metrics::quota_denied` and
//! attributed per client, so a greedy client's overdraft is visible as
//! *its* problem rather than as global load.
//!
//! Place this layer outermost: a denied request should cost one bucket
//! probe, not a queue slot or a decode worker.
//!
//! **Sessions are charged per turn.** Every turn of a multi-turn
//! session spends one token from its client's bucket at admission,
//! exactly like a one-shot request — an open session is pinned state
//! in the coordinator, not prepaid capacity here. A client whose
//! bucket empties mid-session has its next turn denied; the session
//! itself stays pinned (its lease keeps ticking) and the turn can be
//! retried with the same resume key once the bucket refills.
//!
//! Buckets are the crate-private `super::bucket::TokenBucket`, shared
//! with [`super::rate::RateLimit`]; this layer instantiates them
//! fail-*closed* (an invalid rate stops refilling, so a broken config
//! never silently admits everything).

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, Weak};

use crate::coordinator::metrics::{ClientStats, Metrics};

use super::bucket::{InvalidRate, TokenBucket};
use super::{Keyed, Layer, Readiness, Service, ServiceError};

/// Default bound on retained per-client buckets; see
/// [`QuotaConfig::max_clients`].
pub const DEFAULT_QUOTA_CLIENTS: usize = 4096;

/// Per-client and overflow bucket sizing for [`Quota`].
#[derive(Clone, Copy, Debug)]
pub struct QuotaConfig {
    /// Sustained per-client admission rate (tokens/sec, > 0).
    pub rate: f64,
    /// Per-client bucket capacity (burst headroom, min 1 token).
    pub burst: f64,
    /// Shared overflow pool capacity (tokens; 0 disables borrowing).
    pub overflow: f64,
    /// Overflow pool refill rate (tokens/sec).
    pub overflow_rate: f64,
    /// Bound on retained per-client buckets (min 1), so
    /// per-connection client ids cannot grow the map without bound.
    /// Past the cap, registering a new client evicts the
    /// least-recently-used bucket that has refilled to *full* — a
    /// bucket with outstanding debt (spent burst) is never evicted,
    /// since recreating it later would hand the client a fresh burst
    /// and turn eviction into a quota reset. If every bucket carries
    /// debt the map transiently exceeds the cap.
    pub max_clients: usize,
}

impl QuotaConfig {
    /// A quota of `rate` calls/sec with `burst` headroom per client and
    /// an overflow pool of the same size refilled at the same rate.
    pub fn per_client(rate: f64, burst: f64) -> Self {
        QuotaConfig {
            rate,
            burst,
            overflow: burst,
            overflow_rate: rate,
            max_clients: DEFAULT_QUOTA_CLIENTS,
        }
    }
}

impl Default for QuotaConfig {
    fn default() -> Self {
        QuotaConfig::per_client(100.0, 16.0)
    }
}

/// One client's bucket plus its metrics handle. The handle is *weak*:
/// a quota bucket outliving the metrics registry's own client cap must
/// not pin the entry there (see `Metrics::with_client_cap` — eviction
/// skips entries with outstanding strong handles). Denials upgrade it,
/// re-resolving through the registry only if the entry was evicted
/// meanwhile. The touch stamp orders LRU eviction past
/// [`QuotaConfig::max_clients`].
struct ClientBucket {
    bucket: TokenBucket,
    stats: Weak<ClientStats>,
    touch: u64,
}

struct QuotaState {
    buckets: HashMap<String, ClientBucket>,
    overflow: TokenBucket,
    /// Monotonic stamp for LRU ordering (all under the state lock).
    touch_seq: u64,
    /// Skip eviction scans until the map reaches this size again: a
    /// scan that found nothing evictable (every bucket indebted) is
    /// not repeated until the map has grown by another batch, so the
    /// O(map) sweep stays amortized even when nothing can be freed.
    next_evict_scan: usize,
}

/// Per-client admission policy; see the [module docs](self).
///
/// ```
/// use std::sync::Arc;
/// use normq::coordinator::metrics::Metrics;
/// use normq::coordinator::ServeRequest;
/// use normq::service::{Echo, QuotaConfig, Service, ServiceError, Stack};
///
/// let metrics = Arc::new(Metrics::new());
/// // One token of burst, no overflow pool, negligible refill.
/// let cfg = QuotaConfig { rate: 1e-6, burst: 1.0, overflow: 0.0, overflow_rate: 0.0, ..QuotaConfig::default() };
/// let svc = Stack::new()
///     .quota(cfg, Arc::clone(&metrics))
///     .service(Echo::instant());
///
/// let req = |id: &str| ServeRequest::from_client(vec!["tree".into()], id);
/// assert!(svc.call(req("alice")).is_ok());
/// assert_eq!(svc.call(req("alice")), Err(ServiceError::Overloaded));
/// assert!(svc.call(req("bob")).is_ok(), "bob has his own bucket");
/// assert_eq!(metrics.client("alice").quota_denied.load(std::sync::atomic::Ordering::Relaxed), 1);
/// ```
pub struct Quota<S> {
    inner: S,
    cfg: QuotaConfig,
    state: Mutex<QuotaState>,
    metrics: Arc<Metrics>,
}

impl<S> Quota<S> {
    /// Wrap `inner` with the given quota policy. A non-finite or
    /// non-positive `cfg.rate` fails *closed* (the shared bucket's
    /// `FailClosed` resolution: refill rate 0, so each client gets its
    /// burst and is then denied forever) — quota is an admission
    /// policy, so a broken config must never silently admit
    /// everything. CLI entry points reject such rates up front.
    pub fn new(inner: S, cfg: QuotaConfig, metrics: Arc<Metrics>) -> Self {
        let cfg = QuotaConfig {
            rate: cfg.rate,
            burst: cfg.burst.max(1.0),
            overflow: cfg.overflow.max(0.0),
            overflow_rate: cfg.overflow_rate,
            max_clients: cfg.max_clients.max(1),
        };
        Quota {
            inner,
            cfg,
            state: Mutex::new(QuotaState {
                buckets: HashMap::new(),
                overflow: TokenBucket::full(
                    cfg.overflow_rate,
                    cfg.overflow,
                    InvalidRate::FailClosed,
                ),
                touch_seq: 0,
                next_evict_scan: cfg.max_clients,
            }),
            metrics,
        }
    }

    /// Try to admit one call from `client`: own bucket first, then the
    /// shared overflow pool. On denial, returns the client's metrics
    /// handle so the caller attributes it without another registry
    /// lock in the common case.
    fn try_admit(&self, client: &str) -> Result<(), Arc<ClientStats>> {
        let mut st = self.state.lock().unwrap();
        st.touch_seq += 1;
        let stamp = st.touch_seq;
        if let Some(entry) = st.buckets.get_mut(client) {
            entry.touch = stamp;
            if entry.bucket.try_take() {
                return Ok(());
            }
        } else {
            // First sight of this client: bound the map first, then
            // take from a fresh full bucket (burst >= 1 admits).
            if st.buckets.len() >= self.cfg.max_clients.max(st.next_evict_scan) {
                let evicted = Self::evict_idle_buckets(&mut st, self.cfg.burst);
                // Nothing evictable (every bucket indebted): back off
                // so the next sweep waits for another batch of growth.
                st.next_evict_scan = if evicted == 0 {
                    st.buckets.len() + (self.cfg.max_clients / 16).max(1)
                } else {
                    0
                };
            }
            let mut bucket =
                TokenBucket::full(self.cfg.rate, self.cfg.burst, InvalidRate::FailClosed);
            let took = bucket.try_take();
            let stats = Arc::downgrade(&self.metrics.client(client));
            st.buckets
                .insert(client.to_string(), ClientBucket { bucket, stats, touch: stamp });
            if took {
                return Ok(());
            }
        }
        if st.overflow.try_take() {
            return Ok(());
        }
        // Denied: upgrade the cached stats handle; if the metrics
        // registry evicted the entry meanwhile, re-resolve (recreating
        // it) and re-cache the weak handle.
        let entry = st.buckets.get_mut(client).expect("entry ensured above");
        Err(match entry.stats.upgrade() {
            Some(stats) => stats,
            None => {
                let stats = self.metrics.client(client);
                entry.stats = Arc::downgrade(&stats);
                stats
            }
        })
    }

    /// Drop the least-recently-used buckets (up to a ~1/16-of-cap
    /// batch per sweep) that have refilled back to `burst` — no
    /// outstanding debt, so recreating one later grants nothing the
    /// client did not already have. Keeps every indebted bucket, even
    /// past the cap: eviction must never reset a quota. Returns how
    /// many buckets were dropped.
    fn evict_idle_buckets(st: &mut QuotaState, burst: f64) -> usize {
        let batch = (st.buckets.len() / 16).max(1);
        let mut evictable: Vec<(u64, String)> = st
            .buckets
            .iter_mut()
            .filter_map(|(k, e)| {
                // filter_map (not filter): `available` refills, so the
                // predicate needs the mutable borrow by value.
                (e.bucket.available() >= burst - 1e-9).then(|| (e.touch, k.clone()))
            })
            .collect();
        evictable.sort_unstable_by_key(|(touch, _)| *touch);
        let victims: Vec<String> =
            evictable.into_iter().take(batch).map(|(_, k)| k).collect();
        for key in &victims {
            st.buckets.remove(key);
        }
        victims.len()
    }
}

impl<Req, S> Service<Req> for Quota<S>
where
    Req: Keyed,
    S: Service<Req>,
{
    type Response = S::Response;

    /// Advisory only: without a request there is no client to charge,
    /// so the probe just forwards to the inner service.
    fn poll_ready(&self) -> Readiness {
        self.inner.poll_ready()
    }

    fn call(&self, req: Req) -> Result<Self::Response, ServiceError> {
        if let Err(stats) = self.try_admit(req.client_id()) {
            self.metrics.quota_denied.fetch_add(1, Ordering::Relaxed);
            stats.quota_denied.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::Overloaded);
        }
        self.inner.call(req)
    }
}

/// Builds [`Quota`] middlewares; see [`super::stack::Stack::quota`].
#[derive(Clone, Debug)]
pub struct QuotaLayer {
    cfg: QuotaConfig,
    metrics: Arc<Metrics>,
}

impl QuotaLayer {
    /// A layer that wraps services with the given quota policy.
    pub fn new(cfg: QuotaConfig, metrics: Arc<Metrics>) -> Self {
        QuotaLayer { cfg, metrics }
    }
}

impl<S> Layer<S> for QuotaLayer {
    type Service = Quota<S>;
    fn layer(&self, inner: S) -> Self::Service {
        Quota::new(inner, self.cfg, Arc::clone(&self.metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{MockSvc, TestReq};
    use super::*;

    fn cfg(rate: f64, burst: f64, overflow: f64) -> QuotaConfig {
        QuotaConfig { rate, burst, overflow, overflow_rate: rate, ..QuotaConfig::default() }
    }

    #[test]
    fn denies_past_the_burst_per_client() {
        let metrics = Arc::new(Metrics::new());
        let svc = Quota::new(MockSvc::instant(), cfg(1e-9, 2.0, 0.0), Arc::clone(&metrics));
        assert!(svc.call(TestReq::client("a")).is_ok());
        assert!(svc.call(TestReq::client("a")).is_ok());
        assert_eq!(svc.call(TestReq::client("a")), Err(ServiceError::Overloaded));
        // An unrelated client is unaffected.
        assert!(svc.call(TestReq::client("b")).is_ok());
        assert_eq!(metrics.quota_denied.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.client("a").quota_denied.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.client("b").quota_denied.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn overflow_pool_lends_idle_capacity() {
        let metrics = Arc::new(Metrics::new());
        // 1-token bucket + 2-token overflow: three calls pass, not one.
        let svc = Quota::new(MockSvc::instant(), cfg(1e-9, 1.0, 2.0), Arc::clone(&metrics));
        for i in 0..3 {
            assert!(svc.call(TestReq::client("a")).is_ok(), "call {i} denied");
        }
        assert_eq!(svc.call(TestReq::client("a")), Err(ServiceError::Overloaded));
        // The overflow pool is shared: it is empty for everyone now, but
        // b's own bucket still admits one call.
        assert!(svc.call(TestReq::client("b")).is_ok());
        assert_eq!(svc.call(TestReq::client("b")), Err(ServiceError::Overloaded));
    }

    #[test]
    fn invalid_rate_fails_closed() {
        let metrics = Arc::new(Metrics::new());
        // Zero/NaN rates must throttle (burst only), never admit all.
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let svc = Quota::new(MockSvc::instant(), cfg(bad, 1.0, 0.0), Arc::clone(&metrics));
            assert!(svc.call(TestReq::client("a")).is_ok());
            assert_eq!(
                svc.call(TestReq::client("a")),
                Err(ServiceError::Overloaded),
                "rate {bad} failed open"
            );
        }
    }

    #[test]
    fn buckets_refill_over_time() {
        let metrics = Arc::new(Metrics::new());
        let svc = Quota::new(MockSvc::instant(), cfg(1000.0, 1.0, 0.0), Arc::clone(&metrics));
        assert!(svc.call(TestReq::client("a")).is_ok());
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(svc.call(TestReq::client("a")).is_ok(), "bucket should have refilled");
    }

    #[test]
    fn bucket_map_stays_bounded_for_idle_clients() {
        let metrics = Arc::new(Metrics::new());
        // A fast refill: every bucket is instantly full again, so the
        // LRU idle bucket is always evictable.
        let quota = QuotaConfig { max_clients: 4, ..QuotaConfig::per_client(1e9, 2.0) };
        let svc = Quota::new(MockSvc::instant(), quota, Arc::clone(&metrics));
        for i in 0..64 {
            assert!(svc.call(TestReq::client(&format!("conn-{i}"))).is_ok());
        }
        assert_eq!(
            svc.state.lock().unwrap().buckets.len(),
            4,
            "per-connection ids must not grow the bucket map"
        );
    }

    #[test]
    fn eviction_never_resets_an_indebted_bucket() {
        let metrics = Arc::new(Metrics::new());
        // Negligible refill, no overflow pool: a spent bucket stays in
        // debt forever and nothing else admits the client.
        let quota = QuotaConfig { max_clients: 1, ..cfg(1e-9, 1.0, 0.0) };
        let svc = Quota::new(MockSvc::instant(), quota, Arc::clone(&metrics));
        // "debtor" spends its whole burst.
        assert!(svc.call(TestReq::client("debtor")).is_ok());
        assert_eq!(svc.call(TestReq::client("debtor")), Err(ServiceError::Overloaded));
        // New clients arrive past the cap: the indebted bucket must
        // survive (the map exceeds the cap instead).
        assert!(svc.call(TestReq::client("b")).is_ok());
        assert!(svc.state.lock().unwrap().buckets.len() >= 2, "debtor bucket evicted");
        // And the debtor is still denied — its quota was not reset.
        assert_eq!(svc.call(TestReq::client("debtor")), Err(ServiceError::Overloaded));
        assert_eq!(metrics.client("debtor").quota_denied.load(Ordering::Relaxed), 2);
    }
}
