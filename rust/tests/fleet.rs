//! Integration tests for the quality-tiered replica fleet: tier
//! steering, degrade-don't-deny spill, breaker lifecycle, retry-budget
//! exhaustion, and per-tier bit-identity of a real fleet against solo
//! servers of each tier.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use normq::coordinator::fleet::{Fleet, FleetConfig, TierSpec};
use normq::coordinator::metrics::Metrics;
use normq::coordinator::{ServeRequest, Server, ServerConfig, TableBackend};
use normq::data::Corpus;
use normq::generate::DecodeConfig;
use normq::hmm::em::em_step;
use normq::hmm::Hmm;
use normq::lm::NgramLm;
use normq::service::{
    Balance, Breaker, Echo, FaultInjector, FaultPoint, Readiness, RetryBudget, Service,
    ServiceError,
};

/// The shared tiny model every coordinator-backed test serves with.
fn make_model() -> (Arc<NgramLm>, Hmm, Corpus) {
    let corpus = Corpus::small(900);
    let data = corpus.sample_token_corpus(300, 41);
    let lm = Arc::new(NgramLm::train(&data, corpus.vocab.len()));
    let mut rng = normq::util::rng::Rng::seeded(42);
    let mut hmm = Hmm::random(8, corpus.vocab.len(), 0.5, 0.5, &mut rng);
    for _ in 0..4 {
        hmm = em_step(&hmm, &data, 4, 1e-9).0;
    }
    (lm, hmm, corpus)
}

fn base_config(workers: usize) -> ServerConfig {
    ServerConfig {
        workers,
        decode: DecodeConfig { beam: 4, max_tokens: 12, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn weight_steers_entry_tier_over_echo() {
    let metrics = Arc::new(Metrics::new());
    let mut balance = Balance::new(Arc::clone(&metrics));
    balance.register(8, Echo::instant());
    balance.register(4, Echo::instant());
    balance.register(3, Echo::instant());

    let premium = balance
        .call(ServeRequest::from_client(vec!["tree".into()], "vip").with_weight(2))
        .unwrap();
    assert_eq!(premium.tier, 8);
    assert!(!premium.degraded);

    let standard = balance
        .call(ServeRequest::from_client(vec!["tree".into()], "bulk"))
        .unwrap();
    assert_eq!(standard.tier, 4);
    assert!(!standard.degraded);
    assert_eq!(metrics.fleet_routed.load(Ordering::Relaxed), 2);
}

#[test]
fn premium_spills_down_tier_when_entry_is_saturated() {
    let metrics = Arc::new(Metrics::new());
    let mut balance = Balance::new(Arc::clone(&metrics));
    // One slow premium replica with a single dispatch slot; a fast
    // standard tier underneath.
    balance.register(8, Echo::with_delay(Duration::from_millis(60)));
    balance.register(4, Echo::instant());
    let balance = Arc::new(balance.with_depth(1));

    let held = {
        let balance = Arc::clone(&balance);
        std::thread::spawn(move || {
            balance.call(ServeRequest::from_client(vec!["a".into()], "vip").with_weight(2))
        })
    };
    std::thread::sleep(Duration::from_millis(20));
    // The premium slot is occupied: a second premium request must be
    // served by the standard tier and marked degraded — not shed.
    let spilled = balance
        .call(ServeRequest::from_client(vec!["b".into()], "vip").with_weight(2))
        .unwrap();
    assert_eq!(spilled.tier, 4);
    assert!(spilled.degraded);
    assert_eq!(metrics.fleet_degraded.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.fleet_shed.load(Ordering::Relaxed), 0);

    let held = held.join().unwrap().unwrap();
    assert_eq!(held.tier, 8);
    assert!(!held.degraded);
}

#[test]
fn breaker_lifecycle_open_halfopen_close() {
    let metrics = Arc::new(Metrics::new());
    let fault = FaultInjector::new();
    let svc = Breaker::new(FaultPoint::new(Echo::instant(), fault.clone()), Arc::clone(&metrics))
        .with_threshold(2)
        .with_cooldown(Duration::from_millis(50));

    // Trip: two consecutive injected failures.
    fault.set_failing(true);
    for _ in 0..2 {
        let _ = svc.call(ServeRequest::new(vec!["x".into()]));
    }
    assert!(svc.is_open());
    assert_eq!(svc.poll_ready(), Readiness::Busy);
    assert_eq!(metrics.breaker_trips.load(Ordering::Relaxed), 1);

    // Open: fast-fail without touching the backend.
    assert_eq!(
        svc.call(ServeRequest::new(vec!["x".into()])),
        Err(ServiceError::Overloaded)
    );
    assert_eq!(metrics.breaker_rejected.load(Ordering::Relaxed), 1);

    // Half-open after the cooldown: a failed probe re-opens…
    std::thread::sleep(Duration::from_millis(60));
    assert!(matches!(
        svc.call(ServeRequest::new(vec!["x".into()])),
        Err(ServiceError::Failed(_))
    ));
    assert!(svc.is_open());
    assert_eq!(metrics.breaker_trips.load(Ordering::Relaxed), 2);

    // …and after another cooldown a successful probe closes.
    std::thread::sleep(Duration::from_millis(60));
    fault.set_failing(false);
    assert!(svc.call(ServeRequest::new(vec!["back".into()])).is_ok());
    assert!(!svc.is_open());
    assert_eq!(metrics.breaker_probes.load(Ordering::Relaxed), 2);
}

#[test]
fn retry_budget_exhausts_deterministically() {
    let metrics = Arc::new(Metrics::new());
    let fault = FaultInjector::new();
    // No deposits, capacity for exactly two retries.
    let svc = RetryBudget::new(
        FaultPoint::new(Echo::instant(), fault.clone()),
        Arc::clone(&metrics),
    )
    .with_ratio(0.0)
    .with_cap(2.0);

    fault.set_failing(true);
    for _ in 0..3 {
        assert!(matches!(
            svc.call(ServeRequest::new(vec!["x".into()])),
            Err(ServiceError::Failed(_))
        ));
    }
    assert_eq!(metrics.retries.load(Ordering::Relaxed), 2);
    assert_eq!(metrics.retry_exhausted.load(Ordering::Relaxed), 1);
    assert_eq!(svc.balance(), 0.0);
}

/// A premium and a standard request through a real tiered fleet must
/// produce exactly the text a solo server of the serving tier produces
/// — the per-tier bit-identity acceptance check.
#[test]
fn fleet_responses_are_bit_identical_to_solo_tier_servers() {
    let (lm, hmm, corpus) = make_model();
    let concepts = vec![corpus.lexicon.nouns[0].clone()];

    // Solo references, one per tier.
    let mut reference = std::collections::HashMap::new();
    for bits in [8u32, 4] {
        let cfg = ServerConfig {
            table_backend: TableBackend::Quantized { bits },
            ..base_config(2)
        };
        let server = Server::start(Arc::clone(&lm) as _, hmm.clone(), corpus.clone(), cfg);
        let resp = server.call(ServeRequest::new(concepts.clone())).unwrap();
        assert!(!resp.text.is_empty());
        assert_eq!(resp.tier, bits, "solo server must stamp its own backend tier");
        reference.insert(bits, resp.text);
        server.shutdown();
    }

    let fleet = Fleet::start(
        Arc::clone(&lm) as _,
        &hmm,
        &corpus,
        FleetConfig {
            tiers: vec![TierSpec { bits: 8, replicas: 1 }, TierSpec { bits: 4, replicas: 1 }],
            base: base_config(2),
            ..FleetConfig::default()
        },
    );

    let premium = fleet
        .call(ServeRequest::from_client(concepts.clone(), "vip").with_weight(2))
        .unwrap();
    assert_eq!(premium.tier, 8);
    assert!(!premium.degraded);
    assert_eq!(premium.text, reference[&8]);

    let standard = fleet
        .call(ServeRequest::from_client(concepts.clone(), "bulk"))
        .unwrap();
    assert_eq!(standard.tier, 4);
    assert!(!standard.degraded);
    assert_eq!(standard.text, reference[&4]);

    fleet.shutdown();
}

/// Simulated device loss on the premium replica: after the breaker
/// trips, premium traffic keeps being answered (degraded, by the
/// standard tier) and healthy-replica traffic is unaffected.
#[test]
fn breaker_isolates_a_failing_replica_without_failing_the_fleet() {
    let (lm, hmm, corpus) = make_model();
    let concepts = vec![corpus.lexicon.nouns[1].clone()];

    let fleet = Fleet::start(
        Arc::clone(&lm) as _,
        &hmm,
        &corpus,
        FleetConfig {
            tiers: vec![TierSpec { bits: 8, replicas: 1 }, TierSpec { bits: 4, replicas: 1 }],
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_secs(30),
            // No retries: the spill-down path itself must absorb the
            // failure, so the test pins the balancer's behavior.
            retry_budget: 0.0,
            max_retries: 0,
            base: base_config(2),
            ..FleetConfig::default()
        },
    );

    // Healthy warmup on both tiers.
    assert!(fleet
        .call(ServeRequest::from_client(concepts.clone(), "vip").with_weight(2))
        .is_ok());
    assert!(fleet
        .call(ServeRequest::from_client(concepts.clone(), "bulk"))
        .is_ok());

    // Kill the 8-bit replica's device.
    let premium_replica = &fleet.replicas()[0];
    assert_eq!(premium_replica.tier, 8);
    premium_replica.fault.set_failing(true);

    // The first premium calls land on the sick replica and fail while
    // the breaker counts; once it trips, every subsequent premium call
    // is answered by the healthy standard tier, marked degraded.
    let mut failures = 0;
    let mut answered_degraded = 0;
    for _ in 0..6 {
        match fleet.call(ServeRequest::from_client(concepts.clone(), "vip").with_weight(2)) {
            Ok(resp) => {
                assert_eq!(resp.tier, 4);
                assert!(resp.degraded);
                answered_degraded += 1;
            }
            Err(ServiceError::Failed(_)) => failures += 1,
            Err(other) => panic!("unexpected error: {other:?}"),
        }
    }
    assert!(failures <= 2, "breaker must trip at the threshold, saw {failures} failures");
    assert!(answered_degraded >= 4, "post-trip premium traffic must be served degraded");
    assert!(fleet.metrics().breaker_trips.load(Ordering::Relaxed) >= 1);

    // Standard traffic on the healthy replica is unaffected throughout.
    let standard = fleet
        .call(ServeRequest::from_client(concepts.clone(), "bulk"))
        .unwrap();
    assert_eq!(standard.tier, 4);
    assert!(!standard.degraded);

    fleet.shutdown();
}

/// A retry after a replica failure re-runs replica selection, so a
/// fleet WITH a retry budget hides the first failures entirely.
#[test]
fn retry_rereoutes_failures_to_a_healthy_replica() {
    let (lm, hmm, corpus) = make_model();
    let concepts = vec![corpus.lexicon.nouns[2].clone()];

    let fleet = Fleet::start(
        Arc::clone(&lm) as _,
        &hmm,
        &corpus,
        FleetConfig {
            tiers: vec![TierSpec { bits: 8, replicas: 1 }, TierSpec { bits: 4, replicas: 1 }],
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_secs(30),
            retry_budget: 0.1,
            max_retries: 1,
            base: base_config(2),
            ..FleetConfig::default()
        },
    );
    assert!(fleet
        .call(ServeRequest::from_client(concepts.clone(), "bulk"))
        .is_ok());

    fleet.replicas()[0].fault.set_failing(true);
    // Premium calls: the first attempt may fail on the sick replica,
    // but the budgeted retry re-balances. With the breaker still
    // counting, at most the very first call could exhaust its retry on
    // the same sick replica — so allow one failure, require the rest
    // answered.
    let mut answered = 0;
    let mut failed = 0;
    for _ in 0..5 {
        match fleet.call(ServeRequest::from_client(concepts.clone(), "vip").with_weight(2)) {
            Ok(_) => answered += 1,
            Err(_) => failed += 1,
        }
    }
    assert!(answered >= 4, "retries must mask a single replica's failure: {failed} failed");
    let retries = fleet.metrics().retries.load(Ordering::Relaxed);
    assert!(retries >= 1, "the failure path must consume retry budget");
    fleet.shutdown();
}
