//! Quickstart: the whole Norm-Q story in one file.
//!
//! 1. Build a synthetic concept corpus and train the neural part (n-gram
//!    stand-in) and the symbolic part (HMM, via EM).
//! 2. Compress the HMM with Norm-Q at 8 and 3 bits; show the compression
//!    rate and that the model stays a valid probability model.
//! 3. Run constrained generation with each model and compare.
//!
//! Run: cargo run --release --example quickstart

use normq::data::{chunked, Corpus};
use normq::dfa::Dfa;
use normq::generate::{decode, DecodeConfig};
use normq::hmm::Hmm;
use normq::lm::NgramLm;
use normq::qem::{train, QemConfig};
use normq::quant::packed::CompressionReport;
use normq::quant::Method;
use normq::util::rng::Rng;

fn main() {
    normq::util::logging::init_from_env();
    println!("== normq quickstart ==\n");

    // 1. Data + models.
    let corpus = Corpus::new(42);
    println!("corpus: vocab={} words", corpus.vocab.len());
    let train_data = corpus.sample_token_corpus(6000, 43);
    let lm = NgramLm::train(&train_data, corpus.vocab.len());

    let mut rng = Rng::seeded(44);
    let init = Hmm::random(64, corpus.vocab.len(), 0.3, 0.1, &mut rng);
    println!("training HMM (H=64) with EM...");
    let cfg = QemConfig { method: None, epochs: 3, eval_test: false, ..Default::default() };
    let hmm = train(&init, &chunked(train_data, 20), &[], &cfg).model;
    println!("HMM params: {} ({} KB fp32)\n", hmm.param_count(), hmm.fp32_bytes() / 1024);

    // 2. Norm-Q compression.
    for bits in [8u32, 3] {
        let q = Method::NormQ { bits }.apply(&hmm);
        let rt = CompressionReport::of(&hmm.trans, bits);
        let re = CompressionReport::of(&hmm.emit, bits);
        let rate = 1.0
            - (rt.sparse_bits.min(rt.dense_packed_bits) + re.sparse_bits.min(re.dense_packed_bits))
                as f64
                / (rt.fp32_bits + re.fp32_bits) as f64;
        println!(
            "Norm-Q {bits}-bit: valid={} compression={:.3}%",
            q.is_valid(1e-3),
            rate * 100.0
        );
    }
    println!();

    // 3. Constrained generation: "write a sentence containing these".
    let items = corpus.eval_set(5, 1, 45);
    let dcfg = DecodeConfig { beam: 8, max_tokens: 24, ..Default::default() };
    for item in &items {
        let keywords: Vec<Vec<usize>> = item
            .concepts
            .iter()
            .map(|c| vec![corpus.vocab.id(c)])
            .collect();
        let dfa = Dfa::from_keywords(&keywords, corpus.vocab.len());
        println!("concepts: {:?}", item.concepts);
        for (label, model) in [
            ("FP32     ", hmm.clone()),
            ("Norm-Q 8b", Method::NormQ { bits: 8 }.apply(&hmm)),
            ("Norm-Q 3b", Method::NormQ { bits: 3 }.apply(&hmm)),
        ] {
            let g = decode(&lm, &model, &dfa, &dcfg);
            println!(
                "  {label} [{}] {}",
                if g.satisfied { "ok " } else { "MISS" },
                corpus.vocab.decode(&g.tokens)
            );
        }
        println!();
    }
}
