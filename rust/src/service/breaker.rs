//! `Breaker`: a per-replica circuit breaker with half-open probing.
//!
//! Replicas are the first component of the serving fleet that can fail
//! *independently* — a panicking backend, a lost PJRT device, a
//! poisoned build pool. Without a breaker every such failure is
//! discovered by live traffic, over and over: the balancer keeps
//! routing to the dead replica, each request burns its retry budget
//! there, and a single sick backend taxes the whole fleet. The breaker
//! turns repeated failure into *removal from rotation*:
//!
//! - **Closed** (healthy): calls pass through; `threshold` consecutive
//!   failures trip the breaker (`Metrics::breaker_trips`).
//! - **Open**: `poll_ready` reports `Busy` so [`super::balance::Balance`]
//!   steers around the replica, and any call that still arrives
//!   fast-fails with `Err(Overloaded)` without touching the backend
//!   (`Metrics::breaker_rejected`). After `cooldown` the breaker
//!   becomes eligible for a probe.
//! - **Half-open**: exactly one call is admitted as a probe
//!   (`Metrics::breaker_probes`); success closes the breaker, failure
//!   re-opens it for another cooldown.
//!
//! What counts as a failure: `Err(Failed)`, `Err(Closed)`, and a
//! panicking inner call (caught, counted, then resumed — the breaker
//! never swallows a panic). `Overloaded` and `DeadlineExceeded` do
//! *not* count: they are load signals, and tripping on them would turn
//! every overload into an outage.
//!
//! This module also hosts [`FaultInjector`]/[`FaultPoint`] — the
//! fault-injection hook tests and benches use to simulate a replica's
//! device loss (calls fail with `Err(Failed)` while the injector is
//! armed) without touching real backend code.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;

use super::{Layer, Readiness, Service, ServiceError};

/// Default consecutive-failure threshold before the breaker opens.
const DEFAULT_THRESHOLD: u32 = 3;

/// Default cooldown an open breaker waits before admitting a probe.
const DEFAULT_COOLDOWN: Duration = Duration::from_secs(1);

/// The breaker state machine; see the [module docs](self).
#[derive(Clone, Copy, Debug)]
enum State {
    /// Healthy: passing traffic, counting consecutive failures.
    Closed { failures: u32 },
    /// Out of rotation until `until`; calls fast-fail.
    Open { until: Instant },
    /// One probe is in flight; everyone else is held off.
    HalfOpen,
}

/// How a call was admitted (a probe's outcome drives a state change
/// even on success).
#[derive(Clone, Copy)]
enum Admit {
    Normal,
    Probe,
}

/// A circuit breaker wrapping one backend replica; see the
/// [module docs](self).
///
/// ```
/// use std::sync::Arc;
/// use std::time::Duration;
/// use normq::coordinator::metrics::Metrics;
/// use normq::coordinator::ServeRequest;
/// use normq::service::{Breaker, Echo, FaultInjector, FaultPoint, Service, ServiceError};
///
/// let metrics = Arc::new(Metrics::new());
/// let fault = FaultInjector::new();
/// let svc = Breaker::new(FaultPoint::new(Echo::instant(), fault.clone()), Arc::clone(&metrics))
///     .with_threshold(2)
///     .with_cooldown(Duration::from_millis(50));
/// assert!(svc.call(ServeRequest::new(vec!["ok".into()])).is_ok());
///
/// // Simulated device loss: two consecutive failures open the breaker.
/// fault.set_failing(true);
/// for _ in 0..2 {
///     let _ = svc.call(ServeRequest::new(vec!["x".into()]));
/// }
/// assert!(svc.is_open());
/// // While open, calls fast-fail without touching the backend.
/// assert_eq!(
///     svc.call(ServeRequest::new(vec!["x".into()])),
///     Err(ServiceError::Overloaded)
/// );
///
/// // After the cooldown one probe is admitted; the recovered backend
/// // closes the breaker again.
/// std::thread::sleep(Duration::from_millis(60));
/// fault.set_failing(false);
/// assert!(svc.call(ServeRequest::new(vec!["back".into()])).is_ok());
/// assert!(!svc.is_open());
/// ```
pub struct Breaker<S> {
    inner: S,
    threshold: u32,
    cooldown: Duration,
    state: Mutex<State>,
    metrics: Arc<Metrics>,
}

impl<S> Breaker<S> {
    /// Wrap `inner` with a closed breaker (threshold 3, cooldown 1s).
    pub fn new(inner: S, metrics: Arc<Metrics>) -> Self {
        Breaker {
            inner,
            threshold: DEFAULT_THRESHOLD,
            cooldown: DEFAULT_COOLDOWN,
            state: Mutex::new(State::Closed { failures: 0 }),
            metrics,
        }
    }

    /// Consecutive failures that trip the breaker (min 1).
    pub fn with_threshold(mut self, threshold: u32) -> Self {
        self.threshold = threshold.max(1);
        self
    }

    /// How long an open breaker waits before admitting a probe.
    pub fn with_cooldown(mut self, cooldown: Duration) -> Self {
        self.cooldown = cooldown;
        self
    }

    /// True while the breaker is open or probing (out of rotation).
    pub fn is_open(&self) -> bool {
        !matches!(*self.state.lock().unwrap(), State::Closed { .. })
    }

    /// Record one call's outcome and drive the state machine.
    fn record(&self, admit: Admit, failed: bool) {
        let mut state = self.state.lock().unwrap();
        if failed {
            match (*state, admit) {
                // A failed probe re-opens for another cooldown.
                (State::HalfOpen, _) | (_, Admit::Probe) => {
                    *state = State::Open { until: Instant::now() + self.cooldown };
                    self.metrics.breaker_trips.fetch_add(1, Ordering::Relaxed);
                }
                (State::Closed { failures }, Admit::Normal) => {
                    let failures = failures + 1;
                    if failures >= self.threshold {
                        *state = State::Open { until: Instant::now() + self.cooldown };
                        self.metrics.breaker_trips.fetch_add(1, Ordering::Relaxed);
                    } else {
                        *state = State::Closed { failures };
                    }
                }
                // A pre-trip call finishing late while already open:
                // the trip has been counted, nothing to add.
                (State::Open { .. }, Admit::Normal) => {}
            }
        } else {
            match (*state, admit) {
                // A successful probe closes the breaker; a success in
                // Closed resets the consecutive-failure streak.
                (State::HalfOpen, _) | (_, Admit::Probe) | (State::Closed { .. }, _) => {
                    *state = State::Closed { failures: 0 };
                }
                // A straggler succeeding while open does not close the
                // breaker — recovery is confirmed by the probe, whose
                // admission is serialized, not by a call that was
                // already in flight when the replica went sick.
                (State::Open { .. }, Admit::Normal) => {}
            }
        }
    }
}

impl<Req, S> Service<Req> for Breaker<S>
where
    S: Service<Req>,
{
    type Response = S::Response;

    /// `Busy` while open (so the balancer steers around this replica)
    /// and while a probe is in flight; `Ready` once the cooldown has
    /// elapsed (a call now would be admitted as the probe).
    fn poll_ready(&self) -> Readiness {
        let state = *self.state.lock().unwrap();
        match state {
            State::Closed { .. } => self.inner.poll_ready(),
            State::Open { until } => {
                if Instant::now() >= until {
                    Readiness::Ready
                } else {
                    Readiness::Busy
                }
            }
            State::HalfOpen => Readiness::Busy,
        }
    }

    fn call(&self, req: Req) -> Result<Self::Response, ServiceError> {
        let admit = {
            let mut state = self.state.lock().unwrap();
            match *state {
                State::Closed { .. } => Admit::Normal,
                State::Open { until } if Instant::now() >= until => {
                    // Cooldown over: this call becomes the single
                    // probe. The transition happens under the lock, so
                    // concurrent callers cannot both become probes.
                    *state = State::HalfOpen;
                    self.metrics.breaker_probes.fetch_add(1, Ordering::Relaxed);
                    Admit::Probe
                }
                State::Open { .. } | State::HalfOpen => {
                    self.metrics.breaker_rejected.fetch_add(1, Ordering::Relaxed);
                    return Err(ServiceError::Overloaded);
                }
            }
        };
        // A panicking backend must count as a failure (that is the
        // whole point of the breaker), so catch, record, and resume.
        let out = catch_unwind(AssertUnwindSafe(|| self.inner.call(req)));
        let failed = match &out {
            Err(_) => true,
            Ok(Err(ServiceError::Failed(_))) | Ok(Err(ServiceError::Closed)) => true,
            Ok(_) => false,
        };
        self.record(admit, failed);
        match out {
            Ok(result) => result,
            Err(panic) => resume_unwind(panic),
        }
    }
}

/// Builds [`Breaker`] middlewares; see [`super::stack::Stack::breaker`].
#[derive(Clone, Debug)]
pub struct BreakerLayer {
    threshold: u32,
    cooldown: Duration,
    metrics: Arc<Metrics>,
}

impl BreakerLayer {
    /// A layer producing breakers that trip after `threshold`
    /// consecutive failures and probe after `cooldown`.
    pub fn new(threshold: u32, cooldown: Duration, metrics: Arc<Metrics>) -> Self {
        BreakerLayer { threshold, cooldown, metrics }
    }
}

impl<S> Layer<S> for BreakerLayer {
    type Service = Breaker<S>;
    fn layer(&self, inner: S) -> Self::Service {
        Breaker::new(inner, Arc::clone(&self.metrics))
            .with_threshold(self.threshold)
            .with_cooldown(self.cooldown)
    }
}

/// A shared switch that makes a [`FaultPoint`]'s calls fail while
/// armed — the fleet's simulated-device-loss hook. Clone it to keep a
/// control handle outside the service stack:
///
/// ```
/// use normq::coordinator::ServeRequest;
/// use normq::service::{Echo, FaultInjector, FaultPoint, Service};
///
/// let fault = FaultInjector::new();
/// let svc = FaultPoint::new(Echo::instant(), fault.clone());
/// assert!(svc.call(ServeRequest::new(vec!["ok".into()])).is_ok());
/// fault.set_failing(true);
/// assert!(svc.call(ServeRequest::new(vec!["boom".into()])).is_err());
/// ```
#[derive(Clone, Debug, Default)]
pub struct FaultInjector {
    failing: Arc<AtomicBool>,
}

impl FaultInjector {
    /// A disarmed injector (calls pass through).
    pub fn new() -> Self {
        FaultInjector::default()
    }

    /// Arm or disarm the fault: while armed, the attached
    /// [`FaultPoint`] fails every call.
    pub fn set_failing(&self, failing: bool) {
        self.failing.store(failing, Ordering::Relaxed);
    }

    /// True while the fault is armed.
    pub fn failing(&self) -> bool {
        self.failing.load(Ordering::Relaxed)
    }
}

/// A pass-through service that fails with `Err(Failed)` while its
/// [`FaultInjector`] is armed — simulating a replica whose device died
/// mid-service. `poll_ready` stays truthful to the healthy path (a
/// dying device looks ready until a call actually fails), which is
/// exactly the brown-out the breaker exists to catch.
pub struct FaultPoint<S> {
    inner: S,
    injector: FaultInjector,
}

impl<S> FaultPoint<S> {
    /// Wrap `inner`; calls fail while `injector` is armed.
    pub fn new(inner: S, injector: FaultInjector) -> Self {
        FaultPoint { inner, injector }
    }
}

impl<Req, S> Service<Req> for FaultPoint<S>
where
    S: Service<Req>,
{
    type Response = S::Response;

    fn poll_ready(&self) -> Readiness {
        self.inner.poll_ready()
    }

    fn call(&self, req: Req) -> Result<Self::Response, ServiceError> {
        if self.injector.failing() {
            return Err(ServiceError::Failed("injected fault: simulated device loss".into()));
        }
        self.inner.call(req)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{MockSvc, TestReq};
    use super::*;

    fn faulty(metrics: &Arc<Metrics>) -> (Breaker<FaultPoint<MockSvc>>, FaultInjector) {
        let fault = FaultInjector::new();
        let svc = Breaker::new(
            FaultPoint::new(MockSvc::instant(), fault.clone()),
            Arc::clone(metrics),
        )
        .with_threshold(2)
        .with_cooldown(Duration::from_millis(40));
        (svc, fault)
    }

    #[test]
    fn consecutive_failures_trip_the_breaker() {
        let metrics = Arc::new(Metrics::new());
        let (svc, fault) = faulty(&metrics);
        assert!(svc.call(TestReq::default()).is_ok());
        fault.set_failing(true);
        assert!(matches!(svc.call(TestReq::default()), Err(ServiceError::Failed(_))));
        assert!(!svc.is_open(), "one failure below the threshold must not trip");
        assert!(matches!(svc.call(TestReq::default()), Err(ServiceError::Failed(_))));
        assert!(svc.is_open());
        assert_eq!(metrics.breaker_trips.load(Ordering::Relaxed), 1);
        // While open: Busy to the balancer, fast-fail to a caller.
        assert_eq!(svc.poll_ready(), Readiness::Busy);
        assert_eq!(svc.call(TestReq::default()), Err(ServiceError::Overloaded));
        assert_eq!(metrics.breaker_rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn success_resets_the_failure_streak() {
        let metrics = Arc::new(Metrics::new());
        let (svc, fault) = faulty(&metrics);
        fault.set_failing(true);
        let _ = svc.call(TestReq::default());
        fault.set_failing(false);
        assert!(svc.call(TestReq::default()).is_ok());
        fault.set_failing(true);
        let _ = svc.call(TestReq::default());
        // 1 failure, success, 1 failure: never two consecutive.
        assert!(!svc.is_open());
        assert_eq!(metrics.breaker_trips.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn half_open_probe_closes_on_success() {
        let metrics = Arc::new(Metrics::new());
        let (svc, fault) = faulty(&metrics);
        fault.set_failing(true);
        for _ in 0..2 {
            let _ = svc.call(TestReq::default());
        }
        assert!(svc.is_open());
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(svc.poll_ready(), Readiness::Ready, "cooldown over: probe admitted");
        fault.set_failing(false);
        assert!(svc.call(TestReq::default()).is_ok());
        assert!(!svc.is_open());
        assert_eq!(metrics.breaker_probes.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn failed_probe_reopens_for_another_cooldown() {
        let metrics = Arc::new(Metrics::new());
        let (svc, fault) = faulty(&metrics);
        fault.set_failing(true);
        for _ in 0..2 {
            let _ = svc.call(TestReq::default());
        }
        std::thread::sleep(Duration::from_millis(50));
        // The probe itself fails: back to open, another trip counted.
        assert!(matches!(svc.call(TestReq::default()), Err(ServiceError::Failed(_))));
        assert!(svc.is_open());
        assert_eq!(svc.poll_ready(), Readiness::Busy);
        assert_eq!(metrics.breaker_trips.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.breaker_probes.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn overload_errors_do_not_trip() {
        let metrics = Arc::new(Metrics::new());
        let mut inner = MockSvc::instant();
        // MockSvc fails call 0 with Overloaded.
        inner.fail_call = Some(0);
        let svc = Breaker::new(inner, Arc::clone(&metrics)).with_threshold(1);
        assert_eq!(svc.call(TestReq::default()), Err(ServiceError::Overloaded));
        assert!(!svc.is_open(), "load signals must not open the breaker");
        assert!(svc.call(TestReq::default()).is_ok());
    }

    #[test]
    fn panicking_backend_counts_as_failure_and_resumes() {
        struct Panicky;
        impl Service<TestReq> for Panicky {
            type Response = ();
            fn poll_ready(&self) -> Readiness {
                Readiness::Ready
            }
            fn call(&self, _req: TestReq) -> Result<(), ServiceError> {
                panic!("backend died");
            }
        }
        let metrics = Arc::new(Metrics::new());
        let svc = Arc::new(Breaker::new(Panicky, Arc::clone(&metrics)).with_threshold(1));
        let handle = {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || svc.call(TestReq::default()))
        };
        assert!(handle.join().is_err(), "the panic must propagate to the caller");
        assert!(svc.is_open(), "the panic must also count as a breaker failure");
        assert_eq!(metrics.breaker_trips.load(Ordering::Relaxed), 1);
    }
}
