"""Build-time training of the tiny transformer LM on the synthetic
concept corpus (the GPT2-large stand-in). Hand-rolled Adam; a few hundred
steps is plenty for the template grammar. Invoked by aot.py; weights are
then baked into the lowered HLO as constants.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model
from .corpus import Corpus, EOS


def make_batches(corpus: Corpus, n_sentences: int, max_len: int, seed: int):
    """Padded next-token-prediction arrays: inputs [N, T], targets [N, T],
    mask [N, T]. Input position 0 is a BOS (EOS id); targets are the
    sentence tokens."""
    seqs = corpus.sample_token_corpus(n_sentences, seed)
    n = len(seqs)
    inputs = np.zeros((n, max_len), dtype=np.int32)
    targets = np.zeros((n, max_len), dtype=np.int32)
    mask = np.zeros((n, max_len), dtype=np.float32)
    for i, s in enumerate(seqs):
        s = s[: max_len - 1]
        # input[0] is BOS (EOS id); input[t] = s[t-1]; target[t] = s[t].
        inputs[i, 0] = EOS
        if len(s) > 1:
            inputs[i, 1 : len(s)] = s[: len(s) - 1]
        targets[i, : len(s)] = s
        mask[i, : len(s)] = 1.0
    return jnp.array(inputs), jnp.array(targets), jnp.array(mask)


def loss_fn(params, inputs, targets, mask):
    logits = jax.vmap(lambda t: model.lm_forward(params, t))(inputs)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree.map(
        lambda p, m, v: p - lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def train(corpus: Corpus, *, n_sentences=4000, max_len=32, steps=300, batch=128, seed=0,
          d_model=64, n_layers=2, n_heads=4, d_ff=128, verbose=True):
    """Train and return (params, final_loss)."""
    inputs, targets, mask = make_batches(corpus, n_sentences, max_len, seed + 100)
    params = model.init_lm_params(
        jax.random.PRNGKey(seed), corpus.vocab_size(), d_model, n_layers, n_heads, d_ff, max_len
    )
    meta = params.pop("meta")  # keep static meta out of the optimizer
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, idx):
        def loss_with_meta(p):
            return loss_fn({**p, "meta": meta}, inputs[idx], targets[idx], mask[idx])

        loss, grads = jax.value_and_grad(loss_with_meta)(params)
        params, opt = adam_step(params, grads, opt)
        return params, opt, loss

    n = inputs.shape[0]
    rng = np.random.default_rng(seed)
    t0 = time.time()
    loss = float("nan")
    for i in range(steps):
        idx = jnp.array(rng.integers(0, n, size=batch))
        params, opt, loss = step(params, opt, idx)
        if verbose and (i % 50 == 0 or i == steps - 1):
            print(f"  lm train step {i:4d} loss {float(loss):.4f} ({time.time()-t0:.1f}s)")
    params["meta"] = meta
    return params, float(loss)
