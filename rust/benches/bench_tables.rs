//! Table benches: the constraint-table engine trajectory plus the
//! paper-artifact regeneration suite.
//!
//! Part 1 — **table engine**: times `ConstraintTable` builds over the
//! dense FP32 backend vs the sparse quantized backend
//! (`QuantizedHmm`), across bit widths and sparsity levels, serial and
//! parallel. Results always go to `BENCH_tables.json` (the CI
//! bench-smoke artifact that starts the perf trajectory).
//!
//! Part 2 — **artifact regeneration**: runs every experiment driver at
//! a reduced scale and prints the resulting tables with timings,
//! proving all eleven paper artifacts (Tables I-VI, Figs 1-5)
//! regenerate from this repository.
//!
//! `NORMQ_BENCH_QUICK=1` runs part 1 only, at a smaller scale — the
//! CI bench-smoke mode.

use std::time::Instant;

use normq::dfa::Dfa;
use normq::generate::{BuildOptions, ConstraintTable};
use normq::hmm::Hmm;
use normq::quant::QuantizedHmm;
use normq::tables::run_experiment;
use normq::util::cli::Args;
use normq::util::json::Json;
use normq::util::rng::Rng;
use normq::util::timer::time_best_ms;

struct TableRow {
    hidden: usize,
    vocab: usize,
    n_states: usize,
    budget: usize,
    bits: u32,
    alpha: f64,
    sparsity: f64,
    dense_ms: f64,
    sparse_ms: f64,
    sparse_par_ms: f64,
    table_kb: f64,
}

impl TableRow {
    fn speedup(&self) -> f64 {
        self.dense_ms / self.sparse_ms.max(1e-9)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hidden", Json::num(self.hidden as f64)),
            ("vocab", Json::num(self.vocab as f64)),
            ("dfa_states", Json::num(self.n_states as f64)),
            ("budget", Json::num(self.budget as f64)),
            ("bits", Json::num(self.bits)),
            ("alpha", Json::num(self.alpha)),
            ("sparsity", Json::num(self.sparsity)),
            ("dense_ms", Json::num(self.dense_ms)),
            ("sparse_ms", Json::num(self.sparse_ms)),
            ("sparse_par_ms", Json::num(self.sparse_par_ms)),
            ("speedup", Json::num(self.speedup())),
            ("table_kb", Json::num(self.table_kb)),
        ])
    }
}

/// Dense-vs-sparse build scenarios across bit widths and sparsity
/// levels. Both backends dequantize the *same* levels (the dense side
/// is `QuantizedHmm::to_hmm`), so the timing difference is purely the
/// engine exploiting sparsity, not a different model.
fn table_engine_rows(quick: bool) -> Vec<TableRow> {
    let (hiddens, vocab, budget, reps): (&[usize], usize, usize, usize) =
        if quick { (&[64], 300, 16, 2) } else { (&[64, 192], 1000, 32, 3) };
    let threads = normq::util::threadpool::default_threads();
    let mut rng = Rng::seeded(0x7AB1E);
    let mut rows = Vec::new();
    for &hidden in hiddens {
        for &alpha in &[0.05f64, 0.3] {
            let hmm = Hmm::random(hidden, vocab, alpha, alpha, &mut rng);
            // 3 single-token keyword concepts → 8 DFA states.
            let dfa = Dfa::from_keywords(&[vec![5], vec![11], vec![17]], vocab);
            for &bits in &[3u32, 8] {
                let q = QuantizedHmm::from_hmm(&hmm, bits);
                let dense = q.to_hmm();
                let dense_ms =
                    time_best_ms(reps, || drop(ConstraintTable::build(&dense, &dfa, budget)));
                let serial = BuildOptions::default();
                let sparse_ms = time_best_ms(reps, || {
                    ConstraintTable::build_with(&q, &dfa, budget, &serial).unwrap();
                });
                let par = BuildOptions { threads, ..Default::default() };
                let sparse_par_ms = time_best_ms(reps, || {
                    ConstraintTable::build_with(&q, &dfa, budget, &par).unwrap();
                });
                let table = ConstraintTable::build_with(&q, &dfa, budget, &serial).unwrap();
                rows.push(TableRow {
                    hidden,
                    vocab,
                    n_states: dfa.n_states(),
                    budget,
                    bits,
                    alpha,
                    sparsity: q.sparsity(),
                    dense_ms,
                    sparse_ms,
                    sparse_par_ms,
                    table_kb: table.bytes() as f64 / 1024.0,
                });
            }
        }
    }
    rows
}

fn run_table_engine(quick: bool) {
    println!(
        "[bench_tables] table engine: dense vs sparse builds ({})",
        if quick { "quick" } else { "full" }
    );
    println!(
        "{:>6} {:>5} {:>4} {:>8} {:>9} {:>10} {:>13} {:>8} {:>9}",
        "hidden", "alpha", "bits", "sparsity", "dense_ms", "sparse_ms", "sparse_par_ms",
        "speedup", "table_kb"
    );
    let rows = table_engine_rows(quick);
    for r in &rows {
        println!(
            "{:>6} {:>5} {:>4} {:>8.3} {:>9.2} {:>10.2} {:>13.2} {:>7.1}x {:>9.1}",
            r.hidden, r.alpha, r.bits, r.sparsity, r.dense_ms, r.sparse_ms, r.sparse_par_ms,
            r.speedup(), r.table_kb
        );
        if r.bits <= 8 && r.speedup() < 1.0 {
            eprintln!(
                "[bench_tables] WARNING: sparse build slower than dense at bits={} alpha={}",
                r.bits, r.alpha
            );
        }
    }
    let json = Json::obj(vec![
        ("bench", Json::str("tables")),
        ("quick", Json::Bool(quick)),
        ("scenarios", Json::arr(rows.iter().map(|r| r.to_json()))),
    ])
    .to_string();
    match std::fs::write("BENCH_tables.json", &json) {
        Ok(()) => println!("[bench_tables] wrote BENCH_tables.json ({} scenarios)", rows.len()),
        Err(e) => {
            eprintln!("[bench_tables] FAILED writing BENCH_tables.json: {e}");
            std::process::exit(1);
        }
    }
}

fn run_experiment_suite() -> usize {
    // Reduced-scale arguments so the full suite finishes in minutes.
    let base = vec![
        "--items=60".to_string(),
        "--train=3000".to_string(),
        "--epochs=2".to_string(),
        "--beam=6".to_string(),
        "--max-tokens=20".to_string(),
    ];
    let experiments: Vec<(&str, Vec<String>)> = vec![
        ("1", base.clone()),
        ("2", { let mut a = base.clone(); a.push("--bits=16,12,10,8".into()); a }),
        ("3", base.clone()),
        ("4", base.clone()),
        ("5", { let mut a = base.clone(); a.push("--bits=8,4,3".into()); a }),
        ("6", { let mut a = base.clone(); a.push("--scales=2".into()); a.push("--bits=8,3".into()); a }),
        ("fig1", { let mut a = base.clone(); a.push("--requests=8".into()); a }),
        ("fig2", base.clone()),
        ("fig3", { let mut a = base.clone(); a.push("--intervals=1,5,20".into()); a.push("--bits=8".into()); a }),
        ("fig4", { let mut a = base.clone(); a.push("--bits=8,4,3".into()); a }),
        ("fig5", { let mut a = base.clone(); a.push("--intervals=1,20".into()); a }),
    ];
    let mut failures = 0;
    for (id, argv) in experiments {
        let t0 = Instant::now();
        match Args::parse(&argv, &[
            "hidden", "items", "train", "chunks", "epochs", "beam", "max-tokens", "seed",
            "threads", "refs", "lambda",
        ])
        .and_then(|args| run_experiment(id, &args))
        {
            Ok(result) => {
                println!("{}", result.render());
                println!("[bench_tables] {id} regenerated in {:.1}s\n", t0.elapsed().as_secs_f64());
                result.save("results/bench");
            }
            Err(e) => {
                eprintln!("[bench_tables] {id} FAILED: {e}");
                failures += 1;
            }
        }
    }
    failures
}

fn main() {
    normq::util::logging::init_from_env();
    let quick = std::env::var("NORMQ_BENCH_QUICK").is_ok_and(|v| v != "0" && !v.is_empty());
    run_table_engine(quick);
    if quick {
        return;
    }
    let failures = run_experiment_suite();
    if failures > 0 {
        std::process::exit(1);
    }
    println!("[bench_tables] all 11 experiments regenerated");
}
