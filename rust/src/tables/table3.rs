//! Table III — 256-centroid (8-bit) k-means: direct post-training
//! clustering vs k-means-aware EM (interval 20). Expected shape: direct
//! clustering hurts the success rate badly; training with the projection
//! recovers a large part of it.

use crate::eval::evaluate;
use crate::qem::{train, QemConfig};
use crate::quant::Method;
use crate::tables::{score_cells, scores_json, ExperimentContext, TableResult, SCORE_HEADER};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::log_info;

/// Run this experiment and produce its table/figure data.
pub fn run(args: &Args) -> Result<TableResult, String> {
    let ctx = ExperimentContext::build(args)?;
    let bits = args.usize("bits", 8)? as u32;
    let interval = args.usize("interval", 20)?;

    let mut rows = Vec::new();
    let mut json_rows = Vec::new();

    // Direct K-means (no renormalization — the paper's "Direct K-means").
    let direct = Method::Kmeans { bits, renorm: false };
    log_info!("table3: {}", direct.label());
    let hmm_direct = direct.apply(&ctx.hmm);
    let (s_direct, _) =
        evaluate(&ctx.lm, &hmm_direct, &ctx.corpus, &ctx.items, &ctx.decode, ctx.threads);
    rows.push(score_cells("Direct K-means", &s_direct));
    json_rows.push(Json::obj(vec![
        ("method", Json::str("direct")),
        ("scores", scores_json(&s_direct)),
    ]));

    // K-means during EM (normalized projection, as §III-E's alternative).
    log_info!("table3: k-means aware EM (interval {interval})");
    let qcfg = QemConfig {
        method: Some(Method::Kmeans { bits, renorm: true }),
        interval,
        epochs: args.usize("epochs", 3)?,
        threads: ctx.threads,
        eval_test: false,
        ..Default::default()
    };
    let qem = train(&ctx.hmm, &ctx.chunks, &ctx.test_data, &qcfg);
    let (s_qem, _) =
        evaluate(&ctx.lm, &qem.model, &ctx.corpus, &ctx.items, &ctx.decode, ctx.threads);
    rows.push(score_cells("K-means during EM", &s_qem));
    json_rows.push(Json::obj(vec![
        ("method", Json::str("during_em")),
        ("scores", scores_json(&s_qem)),
    ]));

    Ok(TableResult {
        id: "table3".into(),
        title: "256-centroid k-means (paper Table III)".into(),
        header: SCORE_HEADER.iter().map(|s| s.to_string()).collect(),
        rows,
        json: Json::arr(json_rows),
    })
}
