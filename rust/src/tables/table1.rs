//! Table I — ratio-based pruning sweep: 50/80/85/86/90% plus "86% w/
//! norm". Expected shape: success holds through moderate pruning, then a
//! cliff where dead rows appear; renormalization rescues generation at a
//! success-rate cost.

use crate::eval::evaluate;
use crate::quant::Method;
use crate::tables::{score_cells, scores_json, ExperimentContext, TableResult, SCORE_HEADER};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::log_info;

/// Run this experiment and produce its table/figure data.
pub fn run(args: &Args) -> Result<TableResult, String> {
    let ctx = ExperimentContext::build(args)?;
    let ratios = args.f64_list("ratios", &[0.5, 0.8, 0.85, 0.86, 0.9])?;
    let norm_ratio = args.f64("norm-ratio", 0.86)?;

    let mut methods: Vec<Method> = ratios
        .iter()
        .map(|&r| Method::Prune { ratio: r, renorm: false })
        .collect();
    methods.push(Method::Prune { ratio: norm_ratio, renorm: true });

    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for m in methods {
        log_info!("table1: {}", m.label());
        let hmm = m.apply(&ctx.hmm);
        let (scores, _) = evaluate(&ctx.lm, &hmm, &ctx.corpus, &ctx.items, &ctx.decode, ctx.threads);
        rows.push(score_cells(&m.label(), &scores));
        json_rows.push(Json::obj(vec![
            ("method", Json::str(m.label())),
            ("scores", scores_json(&scores)),
        ]));
    }
    Ok(TableResult {
        id: "table1".into(),
        title: "ratio-based pruning (paper Table I)".into(),
        header: SCORE_HEADER.iter().map(|s| s.to_string()).collect(),
        rows,
        json: Json::arr(json_rows),
    })
}
