//! Scaled backward algorithm and posterior (smoothing) computations —
//! the statistics needed by Baum-Welch EM and by the Ctrl-G style
//! constrained decoder (which runs backward messages through a
//! DFA-product, see `crate::generate`).

use crate::hmm::forward::Forward;
use crate::hmm::model::Hmm;

/// Scaled backward messages. betas[t][h] is the backward variable at time
/// t rescaled by the same per-step scales the forward pass produced, so
/// that posterior[t][h] = alphas_pred[t][h] * emit[h,x_t] * betas[t][h]
/// normalizes cleanly.
#[derive(Clone, Debug)]
pub struct Backward {
    /// betas[t][h], rescaled (see the struct docs).
    pub betas: Vec<Vec<f32>>,
}

/// Run the scaled backward pass; `scales` are exp(log_scales) from the
/// forward pass over the same tokens.
pub fn backward(hmm: &Hmm, tokens: &[usize], log_scales: &[f64]) -> Backward {
    let h_n = hmm.hidden();
    let t_n = tokens.len();
    let mut betas = vec![vec![0f32; h_n]; t_n];
    if t_n == 0 {
        return Backward { betas };
    }
    // beta[T-1] = 1
    for b in betas[t_n - 1].iter_mut() {
        *b = 1.0;
    }
    let mut tmp = vec![0f32; h_n];
    for t in (0..t_n - 1).rev() {
        let scale = log_scales[t + 1].exp();
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        // tmp[h'] = emit[h', x_{t+1}] * beta[t+1][h']
        for h2 in 0..h_n {
            tmp[h2] = hmm.emit.at(h2, tokens[t + 1]) * betas[t + 1][h2];
        }
        // beta[t][h] = (Σ_{h'} trans[h,h'] tmp[h']) / scale_{t+1}
        let (head, tail) = betas.split_at_mut(t + 1);
        let row = &mut head[t];
        let _ = tail;
        hmm.trans.matvec(&tmp, row);
        for b in row.iter_mut() {
            *b *= inv as f32;
        }
    }
    Backward { betas }
}

/// State posteriors P(z_t = h | x_{1..T}) for every t.
pub fn posteriors(hmm: &Hmm, tokens: &[usize], fwd: &Forward, bwd: &Backward) -> Vec<Vec<f32>> {
    let t_n = tokens.len();
    let h_n = hmm.hidden();
    let mut out = vec![vec![0f32; h_n]; t_n];
    for t in 0..t_n {
        let mut sum = 0f64;
        for h in 0..h_n {
            let v = fwd.alphas[t][h] as f64 * bwd.betas[t][h] as f64;
            out[t][h] = v as f32;
            sum += v;
        }
        if sum > 0.0 {
            let inv = (1.0 / sum) as f32;
            for v in out[t].iter_mut() {
                *v *= inv;
            }
        }
    }
    out
}

/// Viterbi decoding: most likely state path (log-space; used in tests and
/// the quickstart example to show the model still "makes sense" after
/// quantization).
pub fn viterbi(hmm: &Hmm, tokens: &[usize]) -> (Vec<usize>, f64) {
    let h_n = hmm.hidden();
    let t_n = tokens.len();
    if t_n == 0 {
        return (vec![], 0.0);
    }
    let lf = |x: f32| if x > 0.0 { (x as f64).ln() } else { f64::NEG_INFINITY };
    let mut delta: Vec<f64> = (0..h_n)
        .map(|h| lf(hmm.init[h]) + lf(hmm.emit.at(h, tokens[0])))
        .collect();
    let mut back: Vec<Vec<usize>> = Vec::with_capacity(t_n);
    back.push(vec![0; h_n]);
    for t in 1..t_n {
        let mut next = vec![f64::NEG_INFINITY; h_n];
        let mut ptr = vec![0usize; h_n];
        for h2 in 0..h_n {
            let e = lf(hmm.emit.at(h2, tokens[t]));
            if e == f64::NEG_INFINITY {
                continue;
            }
            for h in 0..h_n {
                let cand = delta[h] + lf(hmm.trans.at(h, h2)) + e;
                if cand > next[h2] {
                    next[h2] = cand;
                    ptr[h2] = h;
                }
            }
        }
        delta = next;
        back.push(ptr);
    }
    let (mut best_h, mut best) = (0usize, f64::NEG_INFINITY);
    for h in 0..h_n {
        if delta[h] > best {
            best = delta[h];
            best_h = h;
        }
    }
    let mut path = vec![0usize; t_n];
    path[t_n - 1] = best_h;
    for t in (1..t_n).rev() {
        path[t - 1] = back[t][path[t]];
    }
    (path, best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmm::forward::forward;
    use crate::util::rng::Rng;

    #[test]
    fn posteriors_normalize() {
        let mut rng = Rng::seeded(21);
        let hmm = Hmm::random(6, 12, 0.4, 0.4, &mut rng);
        let tokens = hmm.sample(10, &mut rng);
        let fwd = forward(&hmm, &tokens);
        let bwd = backward(&hmm, &tokens, &fwd.log_scales);
        for p in posteriors(&hmm, &tokens, &fwd, &bwd) {
            let s: f64 = p.iter().map(|&x| x as f64).sum();
            assert!((s - 1.0).abs() < 1e-4, "sum={s}");
        }
    }

    #[test]
    fn backward_last_step_is_ones() {
        let mut rng = Rng::seeded(22);
        let hmm = Hmm::random(4, 8, 1.0, 1.0, &mut rng);
        let tokens = hmm.sample(5, &mut rng);
        let fwd = forward(&hmm, &tokens);
        let bwd = backward(&hmm, &tokens, &fwd.log_scales);
        assert!(bwd.betas[4].iter().all(|&b| (b - 1.0).abs() < 1e-6));
    }

    /// The forward-backward identity: for every t,
    /// Σ_h alpha_post[t][h] * beta[t][h] should be 1 under our scaling.
    #[test]
    fn forward_backward_identity() {
        let mut rng = Rng::seeded(23);
        let hmm = Hmm::random(5, 9, 0.7, 0.7, &mut rng);
        let tokens = hmm.sample(12, &mut rng);
        let fwd = forward(&hmm, &tokens);
        let bwd = backward(&hmm, &tokens, &fwd.log_scales);
        for t in 0..tokens.len() {
            let s: f64 = (0..5)
                .map(|h| fwd.alphas[t][h] as f64 * bwd.betas[t][h] as f64)
                .sum();
            assert!((s - 1.0).abs() < 1e-4, "t={t} s={s}");
        }
    }

    #[test]
    fn viterbi_path_is_valid_and_scores_match() {
        let mut rng = Rng::seeded(24);
        let hmm = Hmm::random(4, 7, 0.5, 0.5, &mut rng);
        let tokens = hmm.sample(8, &mut rng);
        let (path, score) = viterbi(&hmm, &tokens);
        assert_eq!(path.len(), tokens.len());
        assert!(path.iter().all(|&h| h < 4));
        // Re-score the path manually.
        let mut manual = (hmm.init[path[0]] as f64).ln()
            + (hmm.emit.at(path[0], tokens[0]) as f64).ln();
        for t in 1..tokens.len() {
            manual += (hmm.trans.at(path[t - 1], path[t]) as f64).ln()
                + (hmm.emit.at(path[t], tokens[t]) as f64).ln();
        }
        assert!((score - manual).abs() < 1e-9);
        // Viterbi score <= total likelihood.
        let ll = crate::hmm::forward::log_likelihood(&hmm, &tokens);
        assert!(score <= ll + 1e-9);
    }
}
