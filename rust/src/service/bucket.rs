//! The one token bucket behind both rate-limiting layers.
//!
//! [`super::rate::RateLimit`] (global pacing) and
//! [`super::quota::Quota`] (per-client policy) share the same refill
//! math — continuous refill at `rate` tokens/sec up to a `cap`, one
//! token per admitted call — but differ in what a *broken* rate means:
//! pacing fails **open** (a non-positive/non-finite rate disables
//! pacing; "admit nothing" is a shed policy, not a rate), while quota
//! fails **closed** (a broken admission policy must never silently
//! admit everything). [`TokenBucket`] carries that policy as a
//! constructor parameter so the two layers cannot drift apart again.

use std::time::{Duration, Instant};

/// What a bucket does when constructed with a non-finite or
/// non-positive refill rate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum InvalidRate {
    /// Treat the rate as infinite: the bucket is always full and never
    /// throttles (pacing layers).
    FailOpen,
    /// Treat the rate as zero: the initial burst is all a caller ever
    /// gets (admission-policy layers).
    FailClosed,
}

/// A token bucket: `cap` capacity, continuous refill at `rate`/sec.
#[derive(Debug)]
pub(crate) struct TokenBucket {
    rate: f64,
    cap: f64,
    tokens: f64,
    last_refill: Instant,
}

impl TokenBucket {
    /// A full bucket refilling at `rate` tokens/sec up to `cap`.
    /// Invalid rates resolve per `policy`; `cap` is used as given (a
    /// zero-capacity bucket never admits — quota overflow pools use
    /// that to disable borrowing).
    pub(crate) fn full(rate: f64, cap: f64, policy: InvalidRate) -> TokenBucket {
        let rate = if rate.is_finite() && rate > 0.0 {
            rate
        } else {
            match policy {
                InvalidRate::FailOpen => f64::INFINITY,
                InvalidRate::FailClosed => 0.0,
            }
        };
        TokenBucket { rate, cap, tokens: cap, last_refill: Instant::now() }
    }

    fn refill(&mut self) {
        let now = Instant::now();
        let elapsed = now.duration_since(self.last_refill).as_secs_f64();
        // elapsed * INFINITY is NaN at elapsed == 0; f64::min returns
        // the non-NaN operand, so the fail-open bucket reads as full.
        self.tokens = (self.tokens + elapsed * self.rate).min(self.cap);
        self.last_refill = now;
    }

    /// Refill by elapsed time, then take one token if available.
    pub(crate) fn try_take(&mut self) -> bool {
        self.refill();
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Tokens currently available (after refilling by elapsed time).
    pub(crate) fn available(&mut self) -> f64 {
        self.refill();
        self.tokens
    }

    /// After a failed [`TokenBucket::try_take`]: how long until one
    /// token accrues. `None` when the bucket never refills (rate 0 —
    /// the fail-closed resolution), so callers must not spin-wait.
    pub(crate) fn time_to_token(&self) -> Option<Duration> {
        if self.rate <= 0.0 {
            None
        } else {
            Some(Duration::from_secs_f64((1.0 - self.tokens).max(0.0) / self.rate))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_throttle() {
        let mut b = TokenBucket::full(1e-9, 2.0, InvalidRate::FailClosed);
        assert!(b.try_take());
        assert!(b.try_take());
        assert!(!b.try_take(), "burst exhausted");
    }

    #[test]
    fn refills_over_time() {
        let mut b = TokenBucket::full(1000.0, 1.0, InvalidRate::FailClosed);
        assert!(b.try_take());
        assert!(!b.try_take());
        std::thread::sleep(Duration::from_millis(5));
        assert!(b.try_take(), "should have refilled");
    }

    #[test]
    fn invalid_rate_fails_open_for_pacing() {
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let mut b = TokenBucket::full(bad, 1.0, InvalidRate::FailOpen);
            for i in 0..100 {
                assert!(b.try_take(), "rate {bad} call {i} throttled");
            }
        }
    }

    #[test]
    fn invalid_rate_fails_closed_for_quota() {
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let mut b = TokenBucket::full(bad, 1.0, InvalidRate::FailClosed);
            assert!(b.try_take(), "the initial burst still admits");
            assert!(!b.try_take(), "rate {bad} failed open");
            assert_eq!(b.time_to_token(), None, "no refill to wait for");
        }
    }

    #[test]
    fn time_to_token_matches_the_rate() {
        let mut b = TokenBucket::full(100.0, 1.0, InvalidRate::FailOpen);
        assert!(b.try_take());
        let wait = b.time_to_token().expect("finite rate");
        // One token at 100/s ≈ 10ms away (minus any elapsed refill).
        assert!(wait <= Duration::from_millis(11), "wait={wait:?}");
    }

    #[test]
    fn zero_capacity_never_admits() {
        let mut b = TokenBucket::full(1000.0, 0.0, InvalidRate::FailClosed);
        assert!(!b.try_take());
        std::thread::sleep(Duration::from_millis(2));
        assert!(!b.try_take(), "capacity bounds the refill");
    }
}
