//! Fig 2 — weight-distribution analysis of the trained HMM: log-scale
//! histograms of the transition (α) and emission (β) matrices plus the
//! 64×64 max-pooled heat maps. Expected shape: the overwhelming majority
//! of entries are tiny (paper: >80% below 1e-5 at 4096×50257; the
//! fraction shrinks with matrix size but the skew shape is identical).

use crate::quant::stats::{ascii_heatmap, fraction_below, log_histogram, maxpool_heatmap};
use crate::tables::{ExperimentContext, TableResult};
use crate::util::cli::Args;
use crate::util::json::Json;

/// Run this experiment and produce its table/figure data.
pub fn run(args: &Args) -> Result<TableResult, String> {
    let ctx = ExperimentContext::build(args)?;
    let heat = args.usize("heatmap", 32)?;

    let mut rows = Vec::new();
    let mut json_obj = Vec::new();
    for (name, m) in [("transition (α)", &ctx.hmm.trans), ("emission (β)", &ctx.hmm.emit)] {
        let hist = log_histogram(m);
        let total = m.data.len();
        let mut hist_json = Vec::new();
        for (bucket, count) in &hist {
            rows.push(vec![
                name.to_string(),
                bucket.clone(),
                format!("{count}"),
                format!("{:.2}%", *count as f64 / total as f64 * 100.0),
            ]);
            hist_json.push(Json::obj(vec![
                ("bucket", Json::str(bucket.clone())),
                ("count", Json::num(*count as f64)),
            ]));
        }
        let below = fraction_below(m, 1e-5);
        rows.push(vec![
            name.to_string(),
            "< 1e-5 total".into(),
            String::new(),
            format!("{:.1}%", below * 100.0),
        ]);
        json_obj.push((
            name.to_string(),
            Json::obj(vec![
                ("histogram", Json::arr(hist_json)),
                ("fraction_below_1e-5", Json::num(below)),
            ]),
        ));
        // Print the heat map to stderr (it does not fit table cells).
        let hm = maxpool_heatmap(m, heat);
        eprintln!("heat map {name} (max-pooled to {}x{}):", hm.rows, hm.cols);
        eprintln!("{}", ascii_heatmap(&hm));
    }

    Ok(TableResult {
        id: "fig2".into(),
        title: "HMM weight distribution (paper Fig 2)".into(),
        header: vec!["matrix".into(), "bucket".into(), "count".into(), "share".into()],
        rows,
        json: Json::Obj(json_obj.into_iter().map(|(k, v)| (k, v)).collect()),
    })
}
