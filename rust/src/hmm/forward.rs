//! Scaled forward algorithm (and log-likelihood evaluation).
//!
//! The paper's forward recursion (§II):
//!   P(x_{1..t}, z_{t+1}) = Σ_{z_t} P(z_t, x_{<t}) P(x_t|z_t) P(z_{t+1}|z_t)
//!
//! We run it in linear space with per-step renormalization and track the
//! log of the running scale, which is numerically equivalent to log-space
//! but keeps the hot loop as two dense ops — the exact shape the paper's
//! four "main MatMul layers" (§III-B) refer to, and the shape the Pallas
//! kernel in `python/compile/kernels/hmm_step.py` fuses.

use crate::hmm::backend::HmmBackend;
use crate::hmm::model::Hmm;

/// Result of one forward pass over a sequence.
#[derive(Clone, Debug)]
pub struct Forward {
    /// `alphas[t]` is the *posterior* filtering distribution after
    /// observing token t: `alphas[t][h] = P(z_t = h | x_{1..t})`,
    /// normalized at every step by the running scale.
    pub alphas: Vec<Vec<f32>>,
    /// Per-step log scale factors; their sum is the log-likelihood.
    pub log_scales: Vec<f64>,
}

impl Forward {
    /// Sequence log-likelihood (sum of the per-step log scales).
    pub fn log_likelihood(&self) -> f64 {
        self.log_scales.iter().sum()
    }
}

/// One fused forward step: given the filtering distribution `alpha` over
/// states *before* observing token `tok` at time t (i.e. the predictive
/// P(z_t | x_{<t})), observe `tok` and advance:
///
///   weighted[h]  = alpha[h] * emit[h, tok]
///   scale        = Σ_h weighted[h]            (= P(x_t | x_{<t}))
///   next[h']     = Σ_h (weighted[h]/scale) * trans[h, h']
///
/// Returns the scale. `next` must have length H. This is the L1 kernel's
/// reference semantics (see python/compile/kernels/ref.py::forward_step).
///
/// The implementation lives on [`HmmBackend`] (default method), so any
/// model representation — dense FP32 or sparse quantized levels —
/// advances beliefs the same way, including the uniform-reset guard for
/// scales below ~1e-30 (a token the model gives no real mass; `1/scale`
/// would overflow f32 and poison the belief with `inf·0 = NaN`).
pub fn forward_step(model: &dyn HmmBackend, alpha: &[f32], tok: usize, next: &mut [f32]) -> f64 {
    model.forward_step(alpha, tok, next)
}

/// Full scaled forward pass over `tokens`. Returns filtering
/// distributions and log scales; `log_likelihood()` gives log P(x_{1..T}).
pub fn forward(hmm: &Hmm, tokens: &[usize]) -> Forward {
    let h_n = hmm.hidden();
    let mut alphas = Vec::with_capacity(tokens.len());
    let mut log_scales = Vec::with_capacity(tokens.len());
    let mut alpha = hmm.init.clone();
    let mut next = vec![0f32; h_n];
    for &tok in tokens {
        let scale = forward_step(hmm, &alpha, tok, &mut next);
        // Record the *posterior* filtering distribution at t:
        // alpha[h]*emit[h,tok]/scale. Recompute cheaply from alpha.
        let mut post = vec![0f32; h_n];
        if scale > 0.0 {
            let inv = (1.0 / scale) as f32;
            for h in 0..h_n {
                post[h] = alpha[h] * hmm.emit.at(h, tok) * inv;
            }
        } else {
            post.copy_from_slice(&next); // uniform reset
        }
        alphas.push(post);
        log_scales.push(if scale > 0.0 { scale.ln() } else { f64::NEG_INFINITY });
        std::mem::swap(&mut alpha, &mut next);
    }
    Forward { alphas, log_scales }
}

/// log P(tokens) under the HMM — thin wrapper used everywhere LLD is
/// reported (Figs 4 & 5).
pub fn log_likelihood(hmm: &Hmm, tokens: &[usize]) -> f64 {
    forward(hmm, tokens).log_likelihood()
}

/// Mean per-sequence log-likelihood over a dataset (the paper's test LLD).
pub fn mean_log_likelihood(hmm: &Hmm, dataset: &[Vec<usize>], threads: usize) -> f64 {
    use crate::util::threadpool::parallel_fold;
    if dataset.is_empty() {
        return 0.0;
    }
    let total = parallel_fold(
        dataset.len(),
        threads,
        || 0f64,
        |acc, i| *acc += log_likelihood(hmm, &dataset[i]),
        |a, b| a + b,
    );
    total / dataset.len() as f64
}

/// Brute-force enumeration of P(tokens) — O(H^T), tests only.
#[cfg(test)]
pub fn brute_force_likelihood(hmm: &Hmm, tokens: &[usize]) -> f64 {
    fn rec(hmm: &Hmm, tokens: &[usize], t: usize, z: usize, p: f64) -> f64 {
        if t == tokens.len() {
            return p;
        }
        let pe = p * hmm.emit.at(z, tokens[t]) as f64;
        if t + 1 == tokens.len() {
            return pe;
        }
        let mut total = 0.0;
        for z2 in 0..hmm.hidden() {
            total += rec(hmm, tokens, t + 1, z2, pe * hmm.trans.at(z, z2) as f64);
        }
        total
    }
    let mut total = 0.0;
    for z in 0..hmm.hidden() {
        total += rec(hmm, tokens, 0, z, hmm.init[z] as f64);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{gen, Prop};
    use crate::util::rng::Rng;

    #[test]
    fn forward_matches_brute_force() {
        let mut rng = Rng::seeded(11);
        let hmm = Hmm::random(4, 6, 1.0, 1.0, &mut rng);
        let tokens = vec![0usize, 3, 1, 5, 2];
        let ll = log_likelihood(&hmm, &tokens);
        let bf = brute_force_likelihood(&hmm, &tokens).ln();
        assert!((ll - bf).abs() < 1e-6, "ll={ll} bf={bf}");
    }

    #[test]
    fn forward_property_vs_brute_force() {
        Prop::new(24, 0xF0).run("fwd-vs-bruteforce", |rng, _| {
            let h = rng.range(2, 5);
            let v = rng.range(3, 8);
            let hmm = Hmm::random(h, v, 0.5, 0.5, rng);
            let toks = gen::tokens(rng, v, 5);
            let ll = log_likelihood(&hmm, &toks);
            let bf = brute_force_likelihood(&hmm, &toks).ln();
            assert!((ll - bf).abs() < 1e-5, "ll={ll} bf={bf} h={h} v={v}");
        });
    }

    #[test]
    fn filtering_dists_are_normalized() {
        let mut rng = Rng::seeded(12);
        let hmm = Hmm::random(8, 20, 0.3, 0.2, &mut rng);
        let tokens = hmm.sample(15, &mut rng);
        let fwd = forward(&hmm, &tokens);
        for a in &fwd.alphas {
            let s: f64 = a.iter().map(|&x| x as f64).sum();
            assert!((s - 1.0).abs() < 1e-4, "sum={s}");
        }
    }

    #[test]
    fn impossible_token_gives_neg_inf() {
        let mut rng = Rng::seeded(13);
        let mut hmm = Hmm::random(4, 6, 1.0, 1.0, &mut rng);
        // Make token 5 impossible from every state.
        for h in 0..4 {
            hmm.emit.set(h, 5, 0.0);
        }
        let ll = log_likelihood(&hmm, &[5]);
        assert_eq!(ll, f64::NEG_INFINITY);
    }

    #[test]
    fn longer_sequences_have_lower_likelihood() {
        let mut rng = Rng::seeded(14);
        let hmm = Hmm::random(6, 10, 0.5, 0.5, &mut rng);
        let seq = hmm.sample(30, &mut rng);
        let l10 = log_likelihood(&hmm, &seq[..10]);
        let l30 = log_likelihood(&hmm, &seq);
        assert!(l30 < l10);
    }

    #[test]
    fn mean_lld_parallel_matches_serial() {
        let mut rng = Rng::seeded(15);
        let hmm = Hmm::random(6, 10, 0.5, 0.5, &mut rng);
        let data: Vec<Vec<usize>> = (0..32).map(|_| hmm.sample(12, &mut rng)).collect();
        let a = mean_log_likelihood(&hmm, &data, 1);
        let b = mean_log_likelihood(&hmm, &data, 8);
        assert!((a - b).abs() < 1e-9);
    }
}
